"""Scan-vs-blocked backend parity: the engine's two multicast executions
must agree on results AND on the direction of their I/O accounting.

The blocked backend streams dense Pallas tiles (interpret mode on CPU);
row-exactness is restored by the engine's masking, so outputs must match
the chunked scan path to float tolerance on ANY frontier.  ``messages``
(edge contributions from active majors) is row-exact on both paths and
must match exactly; skip counters count different fetch units (chunks vs
tiles) but must both be zero on a full frontier and both positive on a
block-confined sparse one.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.algs import bc_multisource, bfs_multi, pagerank_pull, pagerank_push
from repro.core import OR_AND, PLUS_TIMES, device_graph, hybrid_spmv, spmv
from repro.graph.generators import erdos_renyi, rmat

pytestmark = pytest.mark.kernel


@pytest.fixture(scope="module")
def sg():
    g = erdos_renyi(200, 1500, seed=1)
    return device_graph(g, chunk_size=256, blocked=True, blocked_reverse=True,
                        bd=32, bs=32)


def _frontiers(n):
    full = jnp.ones(n, bool)
    sparse = jnp.asarray(np.arange(n) < 20)  # confined to source block 0
    return {"full": full, "sparse": sparse}


@pytest.mark.parametrize("direction", ["out", "in"])
@pytest.mark.parametrize("kind", ["full", "sparse"])
def test_spmv_scan_vs_blocked_parity(sg, direction, kind):
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.random(sg.n).astype(np.float32))
    active = _frontiers(sg.n)[kind]
    y_s, st_s = spmv(sg, x, active, PLUS_TIMES, direction=direction,
                     backend="scan")
    y_b, st_b = spmv(sg, x, active, PLUS_TIMES, direction=direction,
                     backend="blocked")
    np.testing.assert_allclose(
        np.asarray(y_s), np.asarray(y_b), atol=1e-5, rtol=1e-5
    )
    # messages are row-exact on both backends: identical.
    assert int(st_s.messages) == int(st_b.messages)
    if kind == "full":
        # nothing skippable on a full frontier, in either fetch unit.
        assert int(st_s.chunks_skipped) == 0
        assert int(st_b.chunks_skipped) == 0
    else:
        # a block-confined frontier must elide fetches on both backends.
        assert int(st_s.chunks_skipped) > 0
        assert int(st_b.chunks_skipped) > 0
        # one I/O request per active vertex whose edges exist.
        assert int(st_b.requests) <= int(jnp.sum(active))
    assert int(st_b.records) > 0


@pytest.mark.parametrize("kind", ["full", "sparse"])
def test_spmv_reverse_parity(sg, kind):
    """Reverse flow (betweenness backward: y[src] += x[dst]) through the
    transposed tile view equals the scan path's reverse gather."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.random(sg.n).astype(np.float32))
    active = _frontiers(sg.n)[kind]
    y_s, st_s = spmv(sg, x, active, PLUS_TIMES, direction="out",
                     reverse=True, backend="scan")
    y_b, st_b = spmv(sg, x, active, PLUS_TIMES, direction="out",
                     reverse=True, backend="blocked")
    np.testing.assert_allclose(
        np.asarray(y_s), np.asarray(y_b), atol=1e-5, rtol=1e-5
    )
    assert int(st_s.messages) == int(st_b.messages)


def test_spmv_or_and_klane_parity(sg):
    """Boolean multi-lane frontier push (the BFS step) is exact, not just
    close: the blocked path thresholds 0/1 tile mass."""
    rng = np.random.default_rng(7)
    xk = jnp.asarray(rng.random((sg.n, 4)) < 0.2)
    active = jnp.asarray(rng.random(sg.n) < 0.3)
    y_s, _ = spmv(sg, xk, active, OR_AND, direction="out", backend="scan")
    y_b, _ = spmv(sg, xk, active, OR_AND, direction="out", backend="blocked")
    assert y_b.dtype == jnp.bool_
    assert bool(jnp.all(y_s == y_b))


def test_hybrid_reaches_blocked_and_p2p(sg):
    """hybrid_spmv(backend='blocked'): dense frontiers run the tile kernel
    (tile-unit skip accounting), sparse frontiers still fall to p2p."""
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.random(sg.n).astype(np.float32))
    full = jnp.ones(sg.n, bool)
    y_h, st_h = hybrid_spmv(sg, x, full, PLUS_TIMES, direction="out",
                            vcap=sg.n, ecap=4 * sg.m, backend="blocked")
    y_b, st_b = spmv(sg, x, full, PLUS_TIMES, direction="out",
                     backend="blocked")
    np.testing.assert_allclose(np.asarray(y_h), np.asarray(y_b), atol=1e-5)
    assert int(st_h.records) == int(st_b.records)

    sparse = jnp.zeros(sg.n, bool).at[3].set(True)
    y_p, st_p = hybrid_spmv(sg, x, sparse, PLUS_TIMES, direction="out",
                            vcap=sg.n, ecap=4 * sg.m, backend="blocked")
    y_s, _ = spmv(sg, x, sparse, PLUS_TIMES, direction="out", backend="scan")
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_s), atol=1e-5)
    # p2p moved only the one live row, far below a whole tile's records.
    assert int(st_p.records) == int(sg.out_degree[3])


def test_spmv_min_plus_parity():
    """min_plus tiles (absent = +inf, unweighted edge = 0 addend) must
    match the scan path wherever either side is finite."""
    g = erdos_renyi(100, 600, seed=4)
    sgm = device_graph(g, chunk_size=128, blocked=True, bd=32, bs=32,
                       blocked_semiring="min_plus")
    from repro.core import MIN_PLUS

    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.random(100).astype(np.float32))
    for active in _frontiers(100).values():
        y_s = np.asarray(spmv(sgm, x, active, MIN_PLUS, backend="scan")[0])
        y_b = np.asarray(spmv(sgm, x, active, MIN_PLUS, backend="blocked")[0])
        assert (np.isinf(y_s) == np.isinf(y_b)).all()
        fin = ~np.isinf(y_s)
        np.testing.assert_allclose(y_s[fin], y_b[fin], atol=1e-5)


def test_or_and_weighted_graph():
    """Boolean reachability must survive hostile weights: plus_times tiles
    bake real weights into the matmul mass (a 0 or cancelling negative
    weight would drop an edge), so weighted graphs must use the 'bool'
    occupancy tiles — and those must match the scan path exactly."""
    from repro.graph.csr import from_edges

    src = np.array([0, 0, 1, 2])
    dst = np.array([1, 2, 3, 3])
    w = np.array([0.0, -1.0, 2.0, 1.0], np.float32)
    g = from_edges(src, dst, n=4, weights=w)
    x = jnp.asarray([[True], [False], [False], [False]])
    act = jnp.ones(4, bool)

    sg_pt = device_graph(g, chunk_size=4, blocked=True, bd=4, bs=4)
    y_s, _ = spmv(sg_pt, x, act, OR_AND, direction="out", backend="scan")
    with pytest.raises(ValueError, match="bool"):
        spmv(sg_pt, x, act, OR_AND, direction="out", backend="blocked")
    sg_bool = device_graph(g, chunk_size=4, blocked=True, bd=4, bs=4,
                           blocked_semiring="bool")
    y_b, _ = spmv(sg_bool, x, act, OR_AND, direction="out", backend="blocked")
    assert bool(jnp.all(y_b == y_s)), (y_b, y_s)


def test_blocked_requires_views():
    g = erdos_renyi(64, 256, seed=0)
    sg_plain = device_graph(g, chunk_size=64)  # no blocked views
    x = jnp.ones(64)
    with pytest.raises(ValueError, match="blocked"):
        spmv(sg_plain, x, jnp.ones(64, bool), PLUS_TIMES, backend="blocked")
    # forward-only views: reverse flow must ask for the opt-in rev build
    sg_fwd = device_graph(g, chunk_size=64, blocked=True, bd=16, bs=16)
    with pytest.raises(ValueError, match="blocked_reverse"):
        spmv(sg_fwd, x, jnp.ones(64, bool), PLUS_TIMES, reverse=True,
             backend="blocked")


def test_blocked_empty_dst_blocks():
    """Destination blocks owning no tiles must come back as the semiring
    identity, not uninitialized memory (the kernel grid never visits
    them)."""
    from repro.core import MIN_PLUS
    from repro.graph.csr import from_edges

    # 64 vertices, edges only among 0..3 -> dst blocks 1..3 own no tiles.
    src = np.array([0, 1, 2, 3])
    dst = np.array([1, 2, 3, 0])
    g = from_edges(src, dst, n=64)
    x = jnp.asarray(np.random.default_rng(0).random(64).astype(np.float32))
    act = jnp.ones(64, bool)

    sg_pt = device_graph(g, chunk_size=16, blocked=True, bd=16, bs=16)
    y_s, _ = spmv(sg_pt, x, act, PLUS_TIMES, backend="scan")
    y_b, _ = spmv(sg_pt, x, act, PLUS_TIMES, backend="blocked")
    assert np.isfinite(np.asarray(y_b)).all()
    np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_b), atol=1e-6)

    sg_mp = device_graph(g, chunk_size=16, blocked=True, bd=16, bs=16,
                         blocked_semiring="min_plus")
    y_s, _ = spmv(sg_mp, x, act, MIN_PLUS, backend="scan")
    y_b, _ = spmv(sg_mp, x, act, MIN_PLUS, backend="blocked")
    ys, yb = np.asarray(y_s), np.asarray(y_b)
    assert not np.isnan(yb).any()
    assert (np.isinf(ys) == np.isinf(yb)).all()  # untouched rows = +inf
    fin = ~np.isinf(ys)
    np.testing.assert_allclose(ys[fin], yb[fin], atol=1e-6)


# ------------------------------------------------ algorithm-level parity
@pytest.fixture(scope="module")
def sg_rmat():
    g = rmat(7, edge_factor=8, seed=2)  # n=128, skewed
    return device_graph(g, chunk_size=256, blocked=True, blocked_reverse=True,
                        bd=32, bs=32)


def test_pagerank_backend_parity(sg_rmat):
    r_s, io_s, it_s = jax.jit(
        lambda: pagerank_push(sg_rmat, tol=1e-4, backend="scan"))()
    r_b, io_b, it_b = jax.jit(
        lambda: pagerank_push(sg_rmat, tol=1e-4, backend="blocked"))()
    assert int(it_s) == int(it_b)
    np.testing.assert_allclose(np.asarray(r_s), np.asarray(r_b), atol=1e-6)
    assert int(io_s.messages) == int(io_b.messages)

    p_s, _, _ = jax.jit(lambda: pagerank_pull(sg_rmat, tol=1e-4, backend="scan"))()
    p_b, _, _ = jax.jit(lambda: pagerank_pull(sg_rmat, tol=1e-4, backend="blocked"))()
    np.testing.assert_allclose(np.asarray(p_s), np.asarray(p_b), atol=1e-6)


def test_bfs_backend_parity(sg_rmat):
    src = jnp.asarray([0, 5, 17, 99], jnp.int32)
    d_s, io_s, _ = jax.jit(lambda: bfs_multi(sg_rmat, src, backend="scan"))()
    d_b, io_b, _ = jax.jit(lambda: bfs_multi(sg_rmat, src, backend="blocked"))()
    assert bool(jnp.all(d_s == d_b))
    assert int(io_s.messages) == int(io_b.messages)
    # draining frontiers must actually skip tiles on the blocked path.
    assert int(io_b.chunks_skipped) > 0


def test_betweenness_backend_parity(sg_rmat):
    src = jnp.asarray([0, 5, 17, 99], jnp.int32)
    b_s, _, _ = jax.jit(lambda: bc_multisource(sg_rmat, src, backend="scan"))()
    b_b, _, _ = jax.jit(lambda: bc_multisource(sg_rmat, src, backend="blocked"))()
    scale = max(float(jnp.max(jnp.abs(b_s))), 1.0)
    np.testing.assert_allclose(
        np.asarray(b_s), np.asarray(b_b), atol=1e-4 * scale
    )
