"""The residency axis: host-resident edge store + streamed supersteps.

Pinned down here:

  * **Bitwise parity** — for BFS and PageRank (push AND pull) on all four
    backends, ``residency='host'`` returns bit-identical values, the same
    superstep count, and field-identical IOStats (``host_bytes`` aside —
    the one residency-sensitive counter) as ``residency='device'``; ditto
    direction='auto' BFS (the Beamer switch must fire identically),
    coreness hybrid/p2p messaging, and multi-source betweenness (the
    reverse-tile flow).  Parity is exercised at ``stream_buffer=2`` too,
    so cross-batch accumulator stitching (chunk order, blocked run
    batching, carry combine) is what's being proven, not a one-batch
    degenerate case.
  * **O(n) device residency** — a host session never builds a device edge
    copy: ``memory_report()`` shows ``device_edge_total == 0`` after a
    full run, with a measured ``peak_stage_bytes`` bounded by TWO stream
    buffers (double buffering's worst case); a device session shows the
    O(m) edge bytes.
  * **Cache correctness** — views are keyed on residency: one HostGraph
    per session, one host tile store per (encoding, reverse, tile_order),
    and no silent fallback from host policy to a device view.
  * **Guards** — host policy × device view and device policy × host view
    each raise the dedicated ValueError; host traversal under ``jax.jit``
    raises (streaming needs concrete frontiers); invalid ``residency`` /
    ``stream_buffer`` values are rejected at policy construction.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import (
    ExecutionPolicy,
    OR_AND,
    device_graph,
    host_graph,
    host_traverse,
    traverse,
)
from repro.graph.generators import rmat

pytestmark = pytest.mark.kernel

BACKENDS = ("scan", "compact", "blocked", "blocked_compact")


@pytest.fixture(scope="module")
def host():
    # Small chunks/tiles: many chunks per superstep, so stream batching
    # and double buffering actually engage.
    return rmat(7, edge_factor=6, seed=3, symmetrize=True)


def sessions(host):
    """A fresh (device session, host session) pair — separate sessions so
    the host one can prove it never built a device view."""
    mk = lambda: repro.Graph(host, chunk_size=128, bd=32, bs=32)
    return mk(), mk()


def assert_result_parity(rd, rh):
    assert np.array_equal(np.asarray(rd.values), np.asarray(rh.values))
    assert int(rd.supersteps) == int(rh.supersteps)
    for name, a, b in zip(rd.iostats._fields, rd.iostats, rh.iostats):
        if name == "host_bytes":
            continue  # the one residency-sensitive (measured) counter
        assert int(a) == int(b), f"IOStats.{name}: {int(a)} != {int(b)}"
    assert int(rh.iostats.host_bytes) > 0  # the stream actually shipped
    assert int(rd.iostats.host_bytes) == 0


# ------------------------------------------------------------ parity
class TestBitwiseParity:
    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("direction", ("out", "auto"))
    def test_bfs(self, host, backend, direction):
        g_d, g_h = sessions(host)
        pol = ExecutionPolicy(backend=backend, direction=direction)
        rd = g_d.bfs(0, policy=pol)
        rh = g_h.bfs(0, policy=pol.with_(residency="host"))
        assert_result_parity(rd, rh)

    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("mode", ("push", "pull"))
    def test_pagerank(self, host, backend, mode):
        g_d, g_h = sessions(host)
        pol = ExecutionPolicy(backend=backend)
        rd = g_d.pagerank(mode=mode, policy=pol, max_iters=20)
        rh = g_h.pagerank(mode=mode, policy=pol.with_(residency="host"),
                          max_iters=20)
        assert_result_parity(rd, rh)

    def test_tiny_stream_buffer(self, host):
        # stream_buffer=2 forces many batches per superstep: cross-batch
        # chunk ordering and the blocked carry-combine are on trial.
        for backend in ("scan", "blocked_compact"):
            g_d, g_h = sessions(host)
            pol = ExecutionPolicy(backend=backend)
            rd = g_d.pagerank(policy=pol, max_iters=15)
            rh = g_h.pagerank(
                policy=pol.with_(residency="host", stream_buffer=2),
                max_iters=15)
            assert_result_parity(rd, rh)

    @pytest.mark.parametrize("messaging", ("hybrid", "p2p"))
    def test_coreness(self, host, messaging):
        g_d, g_h = sessions(host)
        pol = ExecutionPolicy()
        rd = g_d.coreness(messaging=messaging, policy=pol)
        rh = g_h.coreness(messaging=messaging,
                          policy=pol.with_(residency="host"))
        assert_result_parity(rd, rh)

    @pytest.mark.parametrize("backend", ("scan", "blocked_compact"))
    def test_betweenness_multi(self, host, backend):
        g_d, g_h = sessions(host)
        pol = ExecutionPolicy(backend=backend)
        src = jnp.arange(4)
        rd = g_d.betweenness(src, policy=pol)
        rh = g_h.betweenness(src, policy=pol.with_(residency="host"))
        assert_result_parity(rd, rh)

    def test_weighted(self):
        rng = np.random.default_rng(0)
        src = rng.integers(0, 80, 500)
        dst = rng.integers(0, 80, 500)
        w = rng.random(500).astype(np.float32)
        hw = repro.Graph.from_edges(src, dst, weights=w,
                                    symmetrize=True).host
        for backend in ("scan", "blocked"):
            g_d = repro.Graph(hw, chunk_size=128, bd=32, bs=32)
            g_h = repro.Graph(hw, chunk_size=128, bd=32, bs=32)
            pol = ExecutionPolicy(backend=backend)
            rd = g_d.pagerank(policy=pol, max_iters=15)
            rh = g_h.pagerank(policy=pol.with_(residency="host"),
                              max_iters=15)
            assert_result_parity(rd, rh)


# ------------------------------------------------------------ residency
class TestMemoryResidency:
    def test_host_session_keeps_device_edges_at_zero(self, host):
        for backend in ("scan", "blocked_compact"):
            g_h = repro.Graph(host, chunk_size=128, bd=32, bs=32)
            pol = ExecutionPolicy(backend=backend, residency="host",
                                  stream_buffer=4)
            g_h.pagerank(policy=pol, max_iters=10)
            mr = g_h.memory_report(pol)
            assert mr["device_edge_total"] == 0
            assert mr["device_views"] == {}
            assert mr["host_store_bytes"] > 0
            # double buffering: at most TWO staging batches in flight.
            assert 0 < mr["peak_stage_bytes"] <= 2 * mr["stream_buffer_bytes"]

    def test_device_session_shows_o_m_edges(self, host):
        g_d = repro.Graph(host, chunk_size=128, bd=32, bs=32)
        g_d.pagerank(max_iters=3)
        mr = g_d.memory_report()
        # edge-bearing device bytes at least one 8-byte record per edge
        assert mr["device_edge_total"] >= host.m * 8
        assert mr["host_store_bytes"] == 0
        assert mr["peak_stage_bytes"] == 0

    def test_host_store_accounts_tile_views(self, host):
        g_h = repro.Graph(host, chunk_size=128, bd=32, bs=32)
        base = g_h.host_view().store_nbytes
        g_h.bfs(0, policy=ExecutionPolicy(backend="blocked",
                                          residency="host"))
        assert g_h.host_view().store_nbytes > base  # tile store material


# ------------------------------------------------------------ caching
class TestSessionCache:
    def test_host_run_builds_no_device_view(self, host):
        g_h = repro.Graph(host, chunk_size=128, bd=32, bs=32)
        g_h.bfs(0, policy=ExecutionPolicy(backend="blocked_compact",
                                          residency="host"))
        assert g_h._base is None
        assert g_h._tiles == {}

    def test_one_host_view_per_session(self, host):
        g_h = repro.Graph(host, chunk_size=128, bd=32, bs=32)
        pol = ExecutionPolicy(residency="host")
        g_h.bfs(0, policy=pol)
        hv = g_h.host_view()
        g_h.pagerank(policy=pol, max_iters=3)
        assert g_h.host_view() is hv

    def test_one_host_tile_store_per_key(self, host):
        g_h = repro.Graph(host, chunk_size=128, bd=32, bs=32)
        hv = g_h.host_view()
        a = hv.blocked_store("plus_times", reverse=False, tile_order="dest")
        b = hv.blocked_store("plus_times", reverse=False, tile_order="dest")
        assert a is b
        c = hv.blocked_store("min_plus", reverse=False, tile_order="dest")
        assert c is not a
        assert set(hv._blocked) == {
            ("plus_times", False, "dest"), ("min_plus", False, "dest")}

    def test_device_runs_unaffected_by_host_runs(self, host):
        # interleave: device -> host -> device; the device results (from
        # the cached device view) must not change.
        g = repro.Graph(host, chunk_size=128, bd=32, bs=32)
        r1 = g.bfs(0)
        g.bfs(0, policy=ExecutionPolicy(residency="host"))
        r2 = g.bfs(0)
        assert np.array_equal(np.asarray(r1.values), np.asarray(r2.values))
        for a, b in zip(r1.iostats, r2.iostats):
            assert int(a) == int(b)


# ------------------------------------------------------------ guards
class TestGuards:
    def test_policy_validation(self):
        with pytest.raises(ValueError, match="residency"):
            ExecutionPolicy(residency="ssd")
        with pytest.raises(ValueError, match="stream_buffer"):
            ExecutionPolicy(stream_buffer=0)

    def test_host_policy_on_device_graph(self, host):
        sg = device_graph(host, chunk_size=128)
        x = jnp.zeros(sg.n)
        act = jnp.ones(sg.n, bool)
        with pytest.raises(ValueError, match="device-resident graph"):
            traverse(sg, x, act, OR_AND,
                     policy=ExecutionPolicy(residency="host"))

    def test_device_policy_on_host_graph(self, host):
        hg = host_graph(host, chunk_size=128)
        x = jnp.zeros(hg.n)
        act = jnp.ones(hg.n, bool)
        with pytest.raises(ValueError, match="host-resident graph view"):
            traverse(hg, x, act, OR_AND, policy=ExecutionPolicy())

    def test_host_traverse_under_jit(self, host):
        hg = host_graph(host, chunk_size=128)
        pol = ExecutionPolicy(residency="host")

        @jax.jit
        def f(x, act):
            y, _ = host_traverse(hg, x, act, OR_AND, policy=pol)
            return y

        with pytest.raises(ValueError, match="cannot run under jit"):
            f(jnp.zeros(hg.n), jnp.ones(hg.n, bool))

    def test_blocked_triangles_rejected_on_host(self, host):
        g = repro.Graph(host, chunk_size=128, bd=32, bs=32)
        with pytest.raises(ValueError, match="residency='host'"):
            g.triangles(policy=ExecutionPolicy(backend="blocked",
                                               residency="host"))

    def test_traverse_routes_host_view_without_policy_flag(self, host):
        # a host view with a residency='host' policy routes through the
        # streaming engine even via the generic traverse() entry point,
        # and matches the device traverse bitwise.
        sg = device_graph(host, chunk_size=128)
        hg = host_graph(host, chunk_size=128)
        x = jnp.asarray(np.random.default_rng(1).random(host.n),
                        jnp.float32)
        act = jnp.ones(host.n, bool)
        pol = ExecutionPolicy(switch_fraction=None)
        from repro.core import PLUS_TIMES

        yd, std = traverse(sg, x, act, PLUS_TIMES, policy=pol)
        yh, sth = traverse(hg, x, act, PLUS_TIMES,
                           policy=pol.with_(residency="host"))
        assert np.array_equal(np.asarray(yd), np.asarray(yh))
        for name, a, b in zip(std._fields, std, sth):
            if name == "host_bytes":
                continue
            assert int(a) == int(b), name
