"""Chunked online-softmax attention vs the dense oracle (fwd + grads)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.flash import flash_attention, pick_chunk


def dense_ref(q, k, v, qpos, kpos, window, causal, scale):
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,btkh->bkgqt", qg, k.astype(jnp.float32)) * scale
    qp, kp = qpos[:, :, None], kpos[:, None, :]
    valid = kp >= 0
    if causal:
        valid &= kp <= qp
        valid = valid & ((window == 0) | (kp > qp - window))
    s = jnp.where(valid[:, None, None], s, -2.0e38)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,btkh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, hd).astype(q.dtype)


CASES = [
    # b, sq, t, h, kv, hd, causal, window, cq, ck
    (2, 16, 16, 4, 2, 8, True, 0, 4, 8),
    (1, 32, 32, 4, 1, 16, True, 10, 8, 8),
    (2, 24, 24, 6, 6, 8, False, 0, 8, 8),
    (2, 16, 48, 4, 2, 8, True, 0, 16, 16),
    (1, 64, 64, 2, 2, 4, True, 7, 16, 32),
]


@pytest.mark.parametrize("case", CASES)
def test_flash_forward(case):
    b, sq, t, h, kv, hd, causal, window, cq, ck = case
    rng = np.random.default_rng(sum(case[:6]))
    q = jnp.asarray(rng.normal(size=(b, sq, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, t, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, t, kv, hd)), jnp.float32)
    qpos = jnp.broadcast_to(jnp.arange(sq)[None] + (t - sq), (b, sq)).astype(jnp.int32)
    kpos = jnp.broadcast_to(jnp.arange(t)[None], (b, t)).astype(jnp.int32)
    w = jnp.asarray(window, jnp.int32)
    out = flash_attention(q, k, v, qpos, kpos, w, causal, hd**-0.5, cq, ck)
    ref = dense_ref(q, k, v, qpos, kpos, w, causal, hd**-0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("case", CASES[:3])
def test_flash_grads(case):
    b, sq, t, h, kv, hd, causal, window, cq, ck = case
    rng = np.random.default_rng(17)
    q = jnp.asarray(rng.normal(size=(b, sq, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, t, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, t, kv, hd)), jnp.float32)
    qpos = jnp.broadcast_to(jnp.arange(sq)[None] + (t - sq), (b, sq)).astype(jnp.int32)
    kpos = jnp.broadcast_to(jnp.arange(t)[None], (b, t)).astype(jnp.int32)
    w = jnp.asarray(window, jnp.int32)

    def loss_f(q, k, v):
        return jnp.sum(
            flash_attention(q, k, v, qpos, kpos, w, causal, hd**-0.5, cq, ck) ** 2
        )

    def loss_r(q, k, v):
        return jnp.sum(dense_ref(q, k, v, qpos, kpos, w, causal, hd**-0.5) ** 2)

    gf = jax.grad(loss_f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=3e-4, rtol=3e-4)


def test_flash_packed_positions():
    """Two packed sequences in one row: tokens of sequence B must not attend
    to sequence A... they share monotone positions, so causal masking by
    *position* still applies — what matters is the chunk skip stays sound."""
    rng = np.random.default_rng(3)
    b, sq, h, kv, hd = 1, 32, 2, 2, 8
    q = jnp.asarray(rng.normal(size=(b, sq, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, sq, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, sq, kv, hd)), jnp.float32)
    # positions restart mid-row (packing)
    pos = np.concatenate([np.arange(16), np.arange(16)])[None]
    pos = jnp.asarray(pos, jnp.int32)
    w = jnp.zeros((), jnp.int32)
    out = flash_attention(q, k, v, pos, pos, w, True, hd**-0.5, 8, 8)
    ref = dense_ref(q, k, v, pos, pos, w, True, hd**-0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_pick_chunk():
    assert pick_chunk(4096, 512) == 512
    assert pick_chunk(100, 64) == 50
    assert pick_chunk(7, 4) == 1
    assert pick_chunk(32768, 1024) == 1024
