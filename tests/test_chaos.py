"""Chaos gate: real OS worker processes, SIGKILLs and stalls mid-sweep.

The strongest multi-process claim the library makes: a multi-source sweep
served by N>=3 spawned worker processes — two of which are SIGKILL'd
mid-lease (no unwind, no flush) and one of which stalls past its lease
and tries a late commit — merges to *bitwise* the same result (values
plus the order-invariant IOStats ledger) as a crash-free single-process
run, across backends x residencies.  No task is lost, no task commits
twice, and the stale-token rejection count proves the race actually
happened rather than never being exercised.

The in-process :class:`DurableWorkQueue` protocol tests live here too:
they exercise the rename-arbitrated claim/reap/commit transitions that
the OS-level gate then stresses for real.
"""
import time
from pathlib import Path

import numpy as np
import pytest

import repro
from repro.core import (
    DurableWorkQueue,
    ExecutionPolicy,
    ManualClock,
    QueueMismatchError,
    run_workers,
    shard_sources,
)
from repro.distributed.fault import supervise_workers
from repro.graph.generators import rmat

pytestmark = pytest.mark.kernel

# 2 backends x both residencies — the sweep the chaos gate must hold on.
COMBOS = (
    ("scan", "device"),
    ("scan", "host"),
    ("compact", "device"),
    ("compact", "host"),
)
_N_SCALE = 6  # rmat scale: n = 64
_SHARD = 2
_SOURCES = np.arange(8)
_IO_FIELDS = 10  # len(IOStats._fields); checked in the gate test

# Per-worker-process caches (spawn children re-import this module fresh;
# workers persist across tasks, so the session compiles once per combo).
_session_cache: dict = {}


def _get_session():
    s = _session_cache.get("graph")
    if s is None:
        host = rmat(_N_SCALE, edge_factor=6, seed=3, symmetrize=True)
        s = repro.Graph(host, chunk_size=64, bd=32, bs=32)
        _session_cache["graph"] = s
    return s


def _slot_len(n: int) -> int:
    return n * _SHARD + _IO_FIELDS


def chaos_work(payload):
    """One task: a batched multi-source BFS on one (backend, residency)
    combo.  Payload = [combo_idx, src0, src1]; result = a flat float64
    vector, zero outside this combo's slot, holding the (n, Q) distance
    block and the task's IOStats ledger — so the queue's canonical
    additive merge yields per-combo sums of values and of the
    order-invariant I/O totals.  Must be module-level: spawn workers
    pickle it by reference."""
    p = np.asarray(payload, np.int64)
    combo_idx, srcs = int(p[0]), p[1:]
    backend, residency = COMBOS[combo_idx]
    s = _get_session()
    pol = ExecutionPolicy(backend=backend, residency=residency)
    r = s.bfs(np.asarray(srcs, np.int32), policy=pol)
    vals = np.asarray(r.values, np.float64).reshape(-1)
    io = np.asarray([float(v) for v in r.iostats], np.float64)
    out = np.zeros(len(COMBOS) * _slot_len(s.n), np.float64)
    a = combo_idx * _slot_len(s.n)
    out[a:a + vals.size] = vals
    out[a + s.n * _SHARD:a + s.n * _SHARD + io.size] = io
    return out


def _make_tasks() -> list:
    tasks = []
    for ci in range(len(COMBOS)):
        for grp in shard_sources(_SOURCES, _SHARD):
            tasks.append(np.concatenate([[ci], grp]).astype(np.int64))
    return tasks


# ------------------------------------------------------------ the OS gate
class TestChaosGate:
    def test_sigkill_chaos_bitwise_parity(self, tmp_path):
        """3 spawned workers, 2 SIGKILLs + 2 stalls mid-sweep, supervisor
        restarts — merged result bitwise-equal to a crash-free
        single-process run, per combo, with zero lost/double-committed
        tasks and >0 stale-token rejections."""
        from repro.core.sem import IOStats

        assert len(IOStats._fields) == _IO_FIELDS
        tasks = _make_tasks()
        n = 2 ** _N_SCALE
        tpl = np.zeros(len(COMBOS) * _slot_len(n), np.float64)

        # crash-free single-process baseline: one OS worker, no faults
        clean = DurableWorkQueue(tmp_path / "clean", tasks,
                                 lease_timeout=10.0, result_template=tpl)
        rep0 = run_workers(clean, chaos_work, processes=1, timeout=560.0)
        assert rep0.finished and rep0.completed == len(tasks)
        assert rep0.kills == 0 and rep0.stale_rejections == 0
        ref = clean.merge(lambda a, b: a + b)

        # chaos run: kills and stalls spread across combos
        faults = {
            (1, 1): "sigkill",   # combo 0 (scan/device)
            (9, 1): "sigkill",   # combo 2 (compact/device)
            (5, 1): 2.5,         # stall past the lease: combo 1 (scan/host)
            (14, 1): 2.5,        # stall: combo 3 (compact/host)
        }
        chaos = DurableWorkQueue(tmp_path / "chaos", tasks,
                                 lease_timeout=1.5, max_attempts=4,
                                 result_template=tpl)
        rep = run_workers(chaos, chaos_work, processes=3, faults=faults,
                          timeout=560.0)
        assert rep.finished, rep.log
        assert rep.kills >= 2 and rep.restarts >= 2
        assert rep.stale_rejections > 0  # the late commits were refused
        assert rep.dead_letters == []

        # no task lost, none double-committed: exactly one done marker per tid
        done = sorted(p.name for p in (tmp_path / "chaos" / "done").iterdir())
        assert len(done) == len(tasks)
        assert len({m.split(".")[0] for m in done}) == len(tasks)

        merged = chaos.merge(lambda a, b: a + b)
        for ci, (backend, residency) in enumerate(COMBOS):
            a = ci * _slot_len(n)
            seg_ref = ref[a:a + _slot_len(n)]
            seg = merged[a:a + _slot_len(n)]
            assert np.array_equal(seg, seg_ref), (
                f"chaos merge diverged on backend={backend} "
                f"residency={residency}")
        assert np.array_equal(merged, ref)


# ------------------------------------------------------- protocol (fast)
def _vec_work(payload):
    out = np.zeros(4, np.float64)
    out[:2] = np.asarray(payload, np.float64)
    return out


class TestDurableQueueProtocol:
    def make(self, root, **kw):
        kw.setdefault("result_template", np.zeros(4, np.float64))
        kw.setdefault("lease_timeout", 5.0)
        kw.setdefault("clock", ManualClock())
        return DurableWorkQueue(root, [np.array([i, i + 1])
                                       for i in range(5)], **kw)

    def test_claim_is_exclusive_across_attached_queues(self, tmp_path):
        q1 = self.make(tmp_path / "q")
        q2 = self.make(tmp_path / "q")  # attach: same root, same clock era
        l1, l2 = q1.lease(), q2.lease()
        assert {l1.tid, l2.tid} == {0, 1}  # the rename race never double-leases
        assert q1.complete(l1, _vec_work(l1.payload))
        assert q2.complete(l2, _vec_work(l2.payload))

    def test_expiry_reissue_and_stale_rejection(self, tmp_path):
        clock = ManualClock()
        q = self.make(tmp_path / "q", clock=clock)
        l1 = q.lease()
        assert (l1.tid, l1.attempt) == (0, 1)
        clock.advance(6.0)
        l2 = q.lease()  # reaps the expired claim, re-issues as attempt 2
        assert (l2.tid, l2.attempt) == (0, 2)
        assert q.complete(l2, _vec_work(l2.payload))
        # the presumed-dead worker's late commit is refused by the rename
        assert not q.complete(l1, _vec_work(l1.payload))
        assert q.stale_rejections == 1

    def test_renew_extends_lease(self, tmp_path):
        clock = ManualClock()
        q = self.make(tmp_path / "q", clock=clock, lease_timeout=5.0)
        l1 = q.lease()
        clock.advance(4.0)
        q.renew(l1)  # heartbeat: 4s in, extend to t=9
        clock.advance(4.0)
        others = [q.lease() for _ in range(4)]
        assert all(l is not None and l.tid != 0 for l in others)
        assert q.complete(l1, _vec_work(l1.payload))  # still ours at t=8

    def test_dead_letter_after_max_attempts(self, tmp_path):
        clock = ManualClock()
        q = self.make(tmp_path / "q", clock=clock, max_attempts=2)
        for expect in (1, 2):
            l = q.lease()
            assert (l.tid, l.attempt) == (0, expect)
            clock.advance(6.0)  # worker dies; lease expires
        q.lease()  # reap dead-letters tid 0, then claims tid 1
        assert q.dead_letters == [0]

    def test_fail_gives_back_early(self, tmp_path):
        q = self.make(tmp_path / "q")
        l1 = q.lease()
        assert q.fail(l1)
        l2 = q.lease()  # re-issued immediately, no timeout wait
        assert (l2.tid, l2.attempt) == (0, 2)

    def test_attach_resumes_progress_from_filesystem(self, tmp_path):
        q = self.make(tmp_path / "q")
        for _ in range(2):
            l = q.lease()
            q.complete(l, _vec_work(l.payload))
        # process dies here; a fresh attach sees the committed work
        q2 = self.make(tmp_path / "q")
        assert int(q2.completed.sum()) == 2
        while not q2.finished:
            l = q2.lease()
            q2.complete(l, _vec_work(l.payload))
        ref = np.zeros(4)
        for t in q.tasks:
            ref[:2] += t
        assert np.array_equal(q2.merge(lambda a, b: a + b), ref)

    def test_attach_rejects_different_task_set(self, tmp_path):
        self.make(tmp_path / "q")
        with pytest.raises(QueueMismatchError):
            DurableWorkQueue(tmp_path / "q", [np.array([9, 9])],
                             result_template=np.zeros(4))

    def test_merge_folds_committed_attempt_in_canonical_order(self, tmp_path):
        q = self.make(tmp_path / "q")
        leases = [q.lease() for _ in range(5)]
        for l in reversed(leases):  # completion order must not leak
            assert q.complete(l, _vec_work(l.payload))
        fwd = self.make(tmp_path / "q2")
        while not fwd.finished:
            l = fwd.lease()
            fwd.complete(l, _vec_work(l.payload))
        assert np.array_equal(q.merge(lambda a, b: a + b),
                              fwd.merge(lambda a, b: a + b))

    def test_wall_clock_expiry_with_real_processes_semantics(self, tmp_path):
        """Default clock (shared wall time): a worker that stops
        heartbeating loses its task to the next lease() after the
        timeout — no ManualClock, real seconds."""
        q = DurableWorkQueue(tmp_path / "q", [np.array([1, 2])],
                             lease_timeout=0.15,
                             result_template=np.zeros(4))
        l1 = q.lease()
        time.sleep(0.3)  # holder goes silent past the timeout
        l2 = q.lease()
        assert (l2.tid, l2.attempt) == (0, 2)
        assert q.complete(l2, _vec_work(l2.payload))
        assert not q.complete(l1, _vec_work(l1.payload))

    def test_run_workers_processes_requires_durable_queue(self):
        from repro.core import WorkQueue

        q = WorkQueue([np.array([0, 1])], result_template=np.zeros(4),
                      clock=ManualClock())
        with pytest.raises(TypeError, match="DurableWorkQueue"):
            run_workers(q, _vec_work, processes=2)

    def test_supervise_workers_requires_durable_queue(self):
        from repro.core import WorkQueue

        q = WorkQueue([np.array([0, 1])], result_template=np.zeros(4),
                      clock=ManualClock())
        with pytest.raises(TypeError):
            supervise_workers(q, _vec_work)
