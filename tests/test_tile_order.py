"""Tile-order (Hilbert/Morton curve streaming) correctness + invariants.

The acceptance contract of ``ExecutionPolicy.tile_order``: a curve order
changes the blocked backends' streaming SCHEDULE and nothing else —

  * values are bitwise-equal to ``'dest'`` order (gated here on workloads
    whose f32 arithmetic is exact: boolean BFS frontiers, min_plus
    distances, and small-integer plus_times masses — float reorderings of
    inexact sums are checked to 1e-6 via PageRank instead);
  * every :class:`~repro.core.sem.IOStats` field except the new
    ``x_fetches`` counter is order-invariant (requests / records / skips /
    messages / bytes are per-tile sums; only the schedule-sensitive x-DMA
    count may move, and on skewed graphs it must move DOWN);
  * the compacted grid stays bitwise-identical to the full grid under
    every order (run boundaries key on original run ids, so runs are
    never merged by compaction);
  * the generalized ``first``/``last``/``accum`` flags keep their run
    invariants: one ``first`` and one ``last`` per run, constant dbid
    within a run, ``accum=0`` exactly on each block's first run, and
    all-zero ``accum`` under sorted 'dest' order;
  * curve keys are bijections on the pow2 grid, Hilbert consecutive cells
    are Manhattan-adjacent, and the Morton key varies fastest along the
    destination axis (the move that keeps the x block resident).

Also here: the direction-aware p2p capacity buckets (``adaptive_cap``
now re-buckets the sparse arm's vcap/ecap per superstep) must be a pure
wall-clock lever — bitwise values, field-for-field IOStats.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import MIN_PLUS, OR_AND, PLUS_TIMES, device_graph, spmv
from repro.core.engine import ExecutionPolicy, traverse
from repro.graph.generators import path_graph, rmat
from repro.kernels.spmv import (
    TILE_ORDERS,
    blocked_spmv,
    build_blocked,
    compact_tile_order,
    curve_bits,
    hilbert_key,
    morton_key,
    tile_activity,
    x_fetch_count,
)

pytestmark = pytest.mark.kernel

BACKENDS = ("scan", "compact", "blocked", "blocked_compact")
CURVES = ("morton", "hilbert")


@pytest.fixture(scope="module")
def host_g():
    # Skewed (RMAT) so the hub columns recur across destination rows —
    # the regime a curve order exists for.
    return rmat(8, edge_factor=8, seed=3, symmetrize=True)


@pytest.fixture(scope="module")
def session(host_g):
    return repro.Graph(host_g, chunk_size=256, bd=32, bs=32)


def _io_equal_but_x(a, b):
    for name, x, y in zip(a._fields, a, b):
        if name == "x_fetches":
            continue
        assert int(x) == int(y), f"IOStats.{name}: {int(x)} != {int(y)}"


# ------------------------------------------------------- curve invariants
@pytest.mark.parametrize("bits", [1, 2, 3, 5])
def test_hilbert_bijective_and_adjacent(bits):
    n = 1 << bits
    db, sb = np.meshgrid(np.arange(n), np.arange(n), indexing="ij")
    d = hilbert_key(db.ravel(), sb.ravel(), bits)
    assert sorted(d) == list(range(n * n))
    order = np.argsort(d)
    xs, ys = db.ravel()[order], sb.ravel()[order]
    assert (np.abs(np.diff(xs)) + np.abs(np.diff(ys)) == 1).all()


@pytest.mark.parametrize("bits", [1, 2, 3, 5])
def test_morton_bijective_dst_fastest(bits):
    n = 1 << bits
    db, sb = np.meshgrid(np.arange(n), np.arange(n), indexing="ij")
    m = morton_key(db.ravel(), sb.ravel(), bits)
    assert sorted(m) == list(range(n * n))
    # db on the low bits: within a quad the first move is along db,
    # keeping sb (the x block) resident.
    assert morton_key(np.asarray([1]), np.asarray([0]), bits)[0] == 1
    assert morton_key(np.asarray([0]), np.asarray([1]), bits)[0] == 2


def test_curve_bits_covers_grid():
    assert curve_bits(5, 9) == 4  # side 9 -> 16
    assert curve_bits(1, 1) == 1  # degenerate grids still get a 2x2 curve


# ------------------------------------------------- run-flag invariants
@pytest.mark.parametrize("order", TILE_ORDERS)
def test_run_flags_invariants(host_g, order):
    bg = build_blocked(host_g, bd=32, bs=32, tile_order=order)
    dbid = np.asarray(bg.dbid)
    sbid = np.asarray(bg.sbid)
    first = np.asarray(bg.first)
    last = np.asarray(bg.last)
    accum = np.asarray(bg.accum)
    # runs tile the schedule: starts and ends pair up and alternate.
    assert first[0] == 1 and last[-1] == 1
    assert first.sum() == last.sum()
    assert (first[1:] == last[:-1]).all()  # a run ends iff the next starts
    # dbid constant within a run, changes across run boundaries.
    inner = first[1:] == 0
    assert (dbid[1:][inner] == dbid[:-1][inner]).all()
    bound = first[1:] == 1
    assert (dbid[1:][bound] != dbid[:-1][bound]).all()
    # accum: 0 exactly on each block's first run, 1 on every later run.
    starts = np.flatnonzero(first)
    seen = set()
    for s in starts:
        expected = 1 if dbid[s] in seen else 0
        assert accum[s] == expected, f"run at {s}"
        seen.add(dbid[s])
    # accum constant within runs.
    assert (accum[1:][inner] == accum[:-1][inner]).all()
    if order == "dest":
        # sorted order: one run per block, nothing ever re-flushes.
        assert (accum == 0).all()
        assert (np.diff(dbid) >= 0).all()
    else:
        # the same tile multiset, re-scheduled.
        ref = build_blocked(host_g, bd=32, bs=32)
        assert sorted(zip(dbid, sbid)) == sorted(
            zip(np.asarray(ref.dbid), np.asarray(ref.sbid))
        )
        assert int(bg.nnz.sum()) == int(ref.nnz.sum())
        # skewed RMAT: curve orders must create re-flushed runs (else the
        # accumulate-on-flush contract is dead code in this test).
        assert accum.sum() > 0


@pytest.mark.parametrize("order", TILE_ORDERS)
def test_compact_order_preserves_runs(host_g, order):
    """Compacted first/last/accum mark ORIGINAL run boundaries: runs whose
    neighbors die are not merged, and accum re-derives over live runs."""
    bg = build_blocked(host_g, bd=32, bs=32, tile_order=order)
    rng = np.random.default_rng(7)
    act = jnp.asarray((rng.random(bg.num_tiles) < 0.5).astype(np.int32))
    perm, dbid, sbid, first, last, accum, nact = jax.jit(
        lambda a: compact_tile_order(bg, a)
    )(act)
    na = int(nact)
    perm, dbid, first, last, accum = (
        np.asarray(perm), np.asarray(dbid), np.asarray(first),
        np.asarray(last), np.asarray(accum),
    )
    # live prefix is exactly the live tiles, in schedule order.
    assert np.array_equal(perm[:na], np.flatnonzero(np.asarray(act)))
    # tail carries no flags.
    assert first[na:].sum() == last[na:].sum() == accum[na:].sum() == 0
    # each live step's run id comes from the original schedule; boundaries
    # in the compacted order appear exactly where the run id changes.
    run_full = np.cumsum(np.asarray(bg.first)) - 1
    rid = run_full[perm[:na]]
    expect_first = np.ones(na, np.int64)
    expect_first[1:] = rid[1:] != rid[:-1]
    assert np.array_equal(first[:na], expect_first)
    expect_last = np.ones(na, np.int64)
    expect_last[:-1] = rid[1:] != rid[:-1]
    assert np.array_equal(last[:na], expect_last)
    # accum over LIVE runs: first surviving run of each block overwrites.
    seen = set()
    for t in range(na):
        if expect_first[t]:
            assert accum[t] == (1 if dbid[t] in seen else 0), f"step {t}"
            seen.add(dbid[t])


# ------------------------------------------------------------ parity
@pytest.mark.parametrize("order", TILE_ORDERS)
@pytest.mark.parametrize("semiring", ["plus_times", "min_plus", "bool"])
def test_blocked_orders_bitwise_and_compact_parity(host_g, order, semiring):
    """Exact workloads: every order, full AND compacted grid, equals the
    'dest' full grid bit for bit; stats differ only in x_fetches."""
    bg = build_blocked(host_g, bd=32, bs=32, semiring=semiring,
                       tile_order=order)
    ref = build_blocked(host_g, bd=32, bs=32, semiring=semiring)
    rng = np.random.default_rng(11)
    # small integers: f32 sums/mins of these are exact, so reordering the
    # accumulation tree cannot move a single bit.
    x = jnp.asarray(rng.integers(0, 8, host_g.n).astype(np.float32))
    act = jnp.asarray(rng.random(host_g.n) < 0.4)
    y_ref, s_ref = blocked_spmv(ref, x, act, interpret=True)
    y_full, s_full = blocked_spmv(bg, x, act, interpret=True)
    y_cmp, s_cmp = blocked_spmv(bg, x, act, interpret=True, compact=True)
    assert np.array_equal(np.asarray(y_full), np.asarray(y_ref))
    assert np.array_equal(np.asarray(y_cmp), np.asarray(y_full))
    for k in ("tiles_fetched", "tiles_skipped", "tile_bytes", "messages"):
        assert int(s_full[k]) == int(s_ref[k]), k
        assert int(s_cmp[k]) == int(s_full[k]), k
    # x_fetches is schedule-based: identical across full/compacted grids.
    assert int(s_cmp["x_fetches"]) == int(s_full["x_fetches"])


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("order", CURVES)
def test_bfs_bitwise_across_orders(session, backend, order):
    """Multi-source BFS levels AND full IOStats (except x_fetches) are
    bitwise-equal to 'dest' on every backend."""
    src = jnp.asarray([0, 5, 17, 99], jnp.int32)
    mk = lambda o: ExecutionPolicy(backend=backend, tile_order=o,
                                   switch_fraction=None, chunk_cap=16)
    base = jax.jit(lambda: session.bfs(src, policy=mk("dest")))()
    res = jax.jit(lambda: session.bfs(src, policy=mk(order)))()
    assert np.array_equal(np.asarray(res.values), np.asarray(base.values))
    _io_equal_but_x(res.iostats, base.iostats)
    assert int(res.supersteps) == int(base.supersteps)
    if backend in ("blocked", "blocked_compact"):
        # skewed graph: the curve must not cost MORE x DMAs than 'dest'.
        assert int(res.iostats.x_fetches) <= int(base.iostats.x_fetches)
    else:
        # scan paths never touch tiles; the counter stays zero.
        assert int(res.iostats.x_fetches) == int(base.iostats.x_fetches) == 0


@pytest.mark.parametrize("order", CURVES)
def test_pagerank_orders_close(session, order):
    """Inexact f32 masses: reordering moves bits, not answers."""
    base = session.pagerank(tol=1e-4, policy=ExecutionPolicy(backend="blocked"))
    res = session.pagerank(
        tol=1e-4, policy=ExecutionPolicy(backend="blocked", tile_order=order)
    )
    np.testing.assert_allclose(np.asarray(res.values),
                               np.asarray(base.values), atol=1e-6, rtol=1e-6)
    _io_equal_but_x(res.iostats, base.iostats)


@pytest.mark.parametrize("order", CURVES)
def test_min_plus_reverse_and_pull_orders(host_g, order):
    """min_plus tiles, pull direction, and the reverse view all stream the
    curve schedule bitwise-identically ('dest' as oracle)."""
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.integers(0, 16, host_g.n).astype(np.float32))
    act = jnp.asarray(rng.random(host_g.n) < 0.5)
    for kwargs in (dict(blocked_semiring="min_plus"),
                   dict(blocked_semiring="plus_times")):
        sg_d = device_graph(host_g, chunk_size=256, blocked=True,
                            blocked_reverse=True, bd=32, bs=32, **kwargs)
        sg_c = device_graph(host_g, chunk_size=256, blocked=True,
                            blocked_reverse=True, bd=32, bs=32,
                            tile_order=order, **kwargs)
        sr = MIN_PLUS if kwargs["blocked_semiring"] == "min_plus" else PLUS_TIMES
        for call in (
            dict(direction="out"), dict(direction="in"),
            dict(direction="out", reverse=True),
        ):
            y_d, st_d = spmv(sg_d, x, act, sr, backend="blocked", **call)
            y_c, st_c = spmv(sg_c, x, act, sr, backend="blocked", **call)
            assert np.array_equal(np.asarray(y_d), np.asarray(y_c)), call
            _io_equal_but_x(st_c, st_d)


def test_x_fetch_count_model():
    """Hand-checkable schedule: fetch fires on the first live step and on
    every live-to-live source-block change; dead steps never fetch."""
    sbid = jnp.asarray([2, 2, 3, 3, 2, 2], jnp.int32)
    assert int(x_fetch_count(sbid, jnp.ones(6, jnp.int32))) == 3  # 2,3,2
    act = jnp.asarray([0, 1, 0, 1, 1, 0], jnp.int32)
    # live subsequence: sb 2, 3, 2 -> 3 fetches.
    assert int(x_fetch_count(sbid, act)) == 3
    act2 = jnp.asarray([1, 1, 0, 0, 1, 1], jnp.int32)
    # live subsequence: 2, 2, 2, 2 -> a single fetch.
    assert int(x_fetch_count(sbid, act2)) == 1
    assert int(x_fetch_count(sbid, jnp.zeros(6, jnp.int32))) == 0


def test_hilbert_reduces_x_fetches_on_skew(host_g):
    """The acceptance direction: >= 25% fewer x-block DMAs than 'dest' on
    the skewed graph, full frontier."""
    fetches = {}
    for order in TILE_ORDERS:
        bg = build_blocked(host_g, bd=32, bs=32, tile_order=order)
        _, s = blocked_spmv(bg, jnp.ones(host_g.n), None, interpret=True)
        fetches[order] = int(s["x_fetches"])
    assert fetches["hilbert"] <= 0.75 * fetches["dest"], fetches
    assert fetches["morton"] <= 0.75 * fetches["dest"], fetches


def test_policy_validates_tile_order():
    with pytest.raises(ValueError, match="tile_order"):
        ExecutionPolicy(tile_order="zorder")
    with pytest.raises(ValueError, match="tile_order"):
        build_blocked(path_graph(8), bd=4, bs=4, tile_order="snake")


def test_curve_orders_refuse_compiled_tpu_path():
    """The accumulate-on-flush output revisit is validated only in
    interpret mode; the compiled path must refuse curve orders loudly
    instead of risking stale output-window reads on real hardware."""
    bg = build_blocked(path_graph(64), bd=8, bs=8, tile_order="hilbert")
    with pytest.raises(ValueError, match="interpret"):
        blocked_spmv(bg, jnp.ones(64), None, interpret=False)
    # 'dest' keeps the historical single-visit contract: no refusal.
    bg_d = build_blocked(path_graph(64), bd=8, bs=8)
    assert bg_d.tile_order == "dest"


def test_engine_rejects_mismatched_view(host_g):
    sg = device_graph(host_g, chunk_size=256, blocked=True, bd=32, bs=32)
    pol = ExecutionPolicy(backend="blocked", tile_order="hilbert",
                          switch_fraction=None)
    with pytest.raises(ValueError, match="tile_order"):
        traverse(sg, jnp.ones(host_g.n), jnp.ones(host_g.n, bool),
                 PLUS_TIMES, policy=pol)


def test_session_caches_one_view_per_order(host_g, monkeypatch):
    """The session builds each (encoding, order) tile view exactly once and
    holds one copy per order."""
    s = repro.Graph(host_g, chunk_size=256, bd=32, bs=32)
    import repro.graph.session as session_mod
    from repro.kernels import spmv as spmv_mod

    calls = []
    real = spmv_mod.build_blocked

    def counting(*a, **kw):
        calls.append(kw.get("tile_order", "dest"))
        return real(*a, **kw)

    monkeypatch.setattr(spmv_mod, "build_blocked", counting)
    src = jnp.asarray([0, 3], jnp.int32)
    for order in ("hilbert", "dest", "hilbert", "morton", "hilbert"):
        pol = ExecutionPolicy(backend="blocked", tile_order=order,
                              switch_fraction=None)
        s.bfs(src, policy=pol)
    assert sorted(calls) == ["dest", "hilbert", "morton"]
    assert sorted(s._tiles) == [
        ("plus_times", False, "dest"),
        ("plus_times", False, "hilbert"),
        ("plus_times", False, "morton"),
    ]


# ------------------------------------------- adaptive p2p capacity buckets
@pytest.mark.parametrize("gname", ["rmat", "path"])
def test_adaptive_p2p_buckets_bitwise(gname):
    """Re-bucketing the sparse arm's vcap/ecap per superstep is a pure
    wall-clock lever: values, supersteps, and every IOStats field equal the
    static-cap run on both a ballooning (rmat) and a trickling (path)
    frontier."""
    from repro.algs import bfs_uni

    g = (rmat(8, edge_factor=8, seed=5, symmetrize=True) if gname == "rmat"
         else path_graph(512))
    sg = device_graph(g, chunk_size=64)
    out = {}
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        for adaptive in (False, True):
            pol = ExecutionPolicy(
                direction="auto", backend="compact",
                chunk_cap=sg.out_store.num_chunks, adaptive_cap=adaptive,
                switch_fraction=0.10, vcap=max(64, sg.n // 4),
                ecap=max(256, int(sg.m) // 10),
            )
            d, io, it = jax.jit(lambda p=pol: bfs_uni(sg, 0, policy=p))()
            out[adaptive] = (np.asarray(d), tuple(int(v) for v in io), int(it))
    assert np.array_equal(out[True][0], out[False][0])
    assert out[True][1] == out[False][1]
    assert out[True][2] == out[False][2]


def test_adaptive_p2p_single_vertex_frontier():
    """The smallest bucket (vcap=1 band) is actually exercised and exact."""
    g = path_graph(256)
    sg = device_graph(g, chunk_size=32)
    x = jnp.zeros(g.n).at[7].set(1.0)
    act = jnp.zeros(g.n, bool).at[7].set(True)
    pol_s = ExecutionPolicy(switch_fraction=0.5, vcap=64, ecap=128)
    pol_a = pol_s.with_(adaptive_cap=True)
    y_s, st_s = traverse(sg, x, act, PLUS_TIMES, policy=pol_s)
    y_a, st_a = traverse(sg, x, act, PLUS_TIMES, policy=pol_a)
    assert np.array_equal(np.asarray(y_s), np.asarray(y_a))
    assert tuple(int(v) for v in st_s) == tuple(int(v) for v in st_a)
    assert int(st_a.records) == 2  # row-exact: vertex 7's two path edges
