"""Per-architecture smoke tests: reduced same-family configs, one forward
and one train step on CPU, asserting output shapes and no NaNs.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation) — see launch/dryrun.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, cell_is_skipped, get_config, get_smoke, list_archs
from repro.configs.base import TrainConfig
from repro.models import build_model
from repro.optim import adamw_init
from repro.launch.steps import make_decode_step, make_train_step

ARCHS = list_archs()


def _batch(cfg, b=2, s=32):
    batch = {
        "tokens": jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab, (b, s)), jnp.int32
        ),
        "labels": jnp.ones((b, s), jnp.int32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jnp.ones((b, s, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.ones((b, 8, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params, axes = model.init(jax.random.key(0))
    batch = _batch(cfg)
    logits, aux = model.forward(params, batch)
    assert logits.shape == (2, 32, cfg.vocab_padded)
    assert not jnp.isnan(logits).any()
    assert not jnp.isnan(aux)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nan(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params, _ = model.init(jax.random.key(1))
    opt = adamw_init(params)
    step = make_train_step(model, TrainConfig(microbatches=2))
    p2, o2, metrics = jax.jit(step)(params, opt, _batch(cfg))
    assert float(metrics["loss"]) > 0 and not np.isnan(float(metrics["loss"]))
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        params,
        p2,
    )
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_scan_unroll_parity(arch):
    """Scanned and python-unrolled layer stacks agree to bf16 tolerance."""
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params, _ = model.init(jax.random.key(2))
    batch = _batch(cfg)
    l1, _ = model.forward(params, batch)
    l2, _ = model.forward(params, batch, unroll=True)
    a, b = np.asarray(l1, np.float32), np.asarray(l2, np.float32)
    scale = max(np.abs(a).max(), 1.0)
    agree = (a.argmax(-1) == b.argmax(-1)).mean()
    if cfg.family == "moe":
        # bf16 reassociation flips borderline top-k routing on a few
        # tokens, whose logits then legitimately diverge: check the bulk
        # (95th percentile) and greedy agreement instead of the max.
        assert np.percentile(np.abs(a - b), 95) < 0.05 * scale
        assert agree > 0.85
    else:
        assert np.abs(a - b).max() < 0.02 * scale
        assert agree > 0.95


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode(arch):
    """prefill(s tokens) then one decode step: cache-consistent logits."""
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params, _ = model.init(jax.random.key(3))
    b, s = 2, 16
    batch = _batch(cfg, b, s)
    last_logits, cache = model.prefill(params, batch)
    assert last_logits.shape == (b, cfg.vocab_padded)
    assert int(cache["len"]) == s
    step = make_decode_step(model)
    nxt, logits, cache = jax.jit(step)(
        params, cache, jnp.ones((b, 1), jnp.int32)
    )
    assert logits.shape == (b, cfg.vocab_padded)
    assert not jnp.isnan(logits).any()
    assert int(cache["len"]) == s + 1
    assert (np.asarray(nxt) < cfg.vocab).all()  # padding never wins argmax


def test_exact_configs_match_assignment():
    """The exact (non-smoke) configs carry the assigned hyperparameters."""
    spec = {
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
    }
    for arch, (nl, dm, h, kv, ff, vocab) in spec.items():
        cfg = get_config(arch)
        assert cfg.n_layers == nl, arch
        assert cfg.d_model == dm, arch
        if h:
            assert cfg.n_heads == h, arch
            assert cfg.n_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab == vocab, arch
    # MoE structure
    q = get_config("qwen3-moe-235b-a22b")
    assert (q.n_experts, q.top_k) == (128, 8)
    d = get_config("dbrx-132b")
    assert (d.n_experts, d.top_k) == (16, 4)
    # SSM structure
    m = get_config("mamba2-370m")
    assert m.ssm_state == 128
    z = get_config("zamba2-2.7b")
    assert z.ssm_state == 64 and z.family == "hybrid"


def test_cell_skips_documented():
    """long_500k runs only for sub-quadratic archs; every cell resolves."""
    n_run = n_skip = 0
    for arch in ARCHS:
        for shape in SHAPES:
            if cell_is_skipped(arch, shape):
                n_skip += 1
                assert shape == "long_500k"
            else:
                n_run += 1
    assert n_run + n_skip == 40
    assert n_skip == 6  # 10 archs - 4 sub-quadratic
