"""Fault tolerance: checkpoint durability, resume-exact BSP, stream retry,
and the lease-based work queue.

The contract under test is the strongest one the library can make: a run
killed at ANY superstep and resumed is *bitwise-equal* — values, superstep
count, and the full IOStats ledger (``host_bytes`` and ``retries``
included) — to a run that was never interrupted, on every backend and both
residencies; and a multi-source sweep whose workers die mid-lease merges
to exactly the same bits as one where nobody died.
"""
import os
import shutil

import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.checkpoint import (
    CheckpointCorruptionError,
    CheckpointManager,
    latest_step,
    load_extra,
    restore_checkpoint,
    save_checkpoint,
)
from repro.core import (
    CheckpointMismatchError,
    CheckpointSpec,
    DeviceFailure,
    ExecutionPolicy,
    FailurePlan,
    ManualClock,
    QueueMismatchError,
    StreamFailure,
    WorkQueue,
    inject_stream_faults,
    run_program,
    run_supervised,
    run_workers,
    shard_sources,
)
from repro.algs.bfs import BFSProgram
from repro.algs.pagerank import PageRankPullProgram, PageRankPushProgram
from repro.graph.generators import rmat

pytestmark = pytest.mark.kernel

BACKENDS = ("scan", "compact", "blocked", "blocked_compact")


@pytest.fixture(scope="module")
def host():
    # Small enough that kill-at-every-superstep sweeps stay fast, chunked
    # small enough that host streaming ships several batches per superstep.
    return rmat(6, edge_factor=6, seed=3, symmetrize=True)


def session(host):
    return repro.Graph(host, chunk_size=64, bd=32, bs=32)


def views(host):
    s = session(host)
    return s.device(), s.host_view()


def assert_identical(a, b, *, skip=()):
    """Full bitwise equality: values, supersteps, EVERY IOStats field."""
    assert np.array_equal(np.asarray(a.values), np.asarray(b.values))
    assert int(a.supersteps) == int(b.supersteps)
    for name, x, y in zip(a.iostats._fields, a.iostats, b.iostats):
        if name in skip:
            continue
        assert int(x) == int(y), f"IOStats.{name}: {int(x)} != {int(y)}"


# ------------------------------------------------------------ store
class TestStoreDurability:
    def test_tmp_partial_and_stray_entries_ignored(self, tmp_path):
        tree = {"a": jnp.arange(5), "b": jnp.ones(3)}
        save_checkpoint(tmp_path, 4, tree)
        # a crashed save leaves a .tmp; stray dirs happen to real operators
        (tmp_path / "step_00000099.tmp").mkdir()
        (tmp_path / "step_junk").mkdir()
        (tmp_path / "step_").mkdir()
        assert latest_step(tmp_path) == 4
        restored, step = restore_checkpoint(
            tmp_path, {"a": jnp.zeros(5, jnp.int32), "b": jnp.zeros(3)})
        assert step == 4
        assert np.array_equal(np.asarray(restored["a"]), np.arange(5))
        # retention gc must also step over the strays
        mgr = CheckpointManager(tmp_path, keep=1)
        mgr.save(7, tree)
        assert latest_step(tmp_path) == 7

    def test_corrupt_shard_is_an_error(self, tmp_path):
        save_checkpoint(tmp_path, 1, {"a": jnp.arange(4), "b": jnp.ones(2)})
        shard = tmp_path / "step_00000001" / "proc0.npz"
        np.savez(shard, a0=np.arange(4))  # one leaf missing
        with pytest.raises(CheckpointCorruptionError, match="manifest"):
            restore_checkpoint(
                tmp_path, {"a": jnp.zeros(4, jnp.int32), "b": jnp.zeros(2)})

    def test_extra_metadata_round_trip(self, tmp_path):
        save_checkpoint(tmp_path, 2, {"a": jnp.zeros(1)},
                        extra={"graph": "abc", "superstep": 2})
        assert load_extra(tmp_path, 2) == {"graph": "abc", "superstep": 2}
        assert load_extra(tmp_path, 3) is None

    def test_as_numpy_preserves_dtypes(self, tmp_path):
        save_checkpoint(tmp_path, 1, {"r": np.arange(3, dtype=np.float64)})
        tree, _ = restore_checkpoint(
            tmp_path, {"r": np.zeros(3, np.float64)}, as_numpy=True)
        assert tree["r"].dtype == np.float64


# ------------------------------------------------------------ resume-exact
class TestResumeExact:
    def test_kill_at_every_superstep(self, host, tmp_path):
        """The headline contract, exhaustively on one backend: crash at
        superstep k for EVERY k, resume, and the result is bitwise the
        uninterrupted run's — wherever k falls relative to every_k."""
        sem, _ = views(host)
        prog = PageRankPullProgram(tol=1e-4)
        base = run_program(sem, prog, max_supersteps=30)
        total = int(base.supersteps)
        assert total > 5
        for k in range(total):
            d = tmp_path / f"kill_{k}"
            res, rep = run_supervised(
                sem, prog, max_supersteps=30,
                checkpoint=CheckpointSpec(d, every_k=3),
                plan=FailurePlan({k: "crash"}))
            assert rep.restarts == 1
            assert_identical(base, res)

    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("residency", ("device", "host"))
    def test_backends_and_residencies(self, host, tmp_path, backend,
                                      residency):
        """Spot kills on every backend x residency: PageRank killed twice
        (once off-cadence), BFS killed once.  host_bytes and retries are
        compared too — same-residency runs must agree on the whole
        ledger."""
        s = session(host)
        pol = ExecutionPolicy(backend=backend, residency=residency)
        prog = PageRankPullProgram(tol=1e-4)
        sem = s._sem(pol, prog)  # the view the façade would run this on
        base = run_program(sem, prog, pol, max_supersteps=25)
        res, rep = run_supervised(
            sem, prog, pol, max_supersteps=25,
            checkpoint=CheckpointSpec(tmp_path / "pr", every_k=2),
            plan=FailurePlan({3: "crash", 7: "crash"}))
        assert rep.restarts == 2
        assert_identical(base, res)

        bfs = BFSProgram()
        seeds = jnp.asarray([0], jnp.int32)
        sem = s._sem(pol, bfs)
        base_b = run_program(sem, bfs, pol, seeds=seeds)
        res_b, _ = run_supervised(
            sem, bfs, pol, seeds=seeds,
            checkpoint=CheckpointSpec(tmp_path / "bfs", every_k=2),
            plan=FailurePlan({2: "crash"}))
        assert_identical(base_b, res_b)

    @pytest.mark.parametrize("residency", ("device", "host"))
    def test_betweenness_phase_checkpoints(self, host, tmp_path, residency):
        """A kill in the backward sweep resumes there; the forward phase
        replays from its final snapshot (its own `fwd/` subtree)."""
        s_base, s_ck = session(host), session(host)
        pol = ExecutionPolicy(backend="scan", residency=residency)
        src = jnp.arange(3)
        base = s_base.betweenness(src, policy=pol)
        spec = CheckpointSpec(tmp_path / "bc", every_k=2)
        ck = s_ck.betweenness(src, policy=pol, checkpoint=spec)
        assert_identical(base, ck)
        assert (tmp_path / "bc" / "fwd").is_dir()
        assert (tmp_path / "bc" / "bwd").is_dir()
        again = s_ck.betweenness(src, policy=pol, checkpoint=spec,
                                 resume=True)
        assert_identical(base, again)

    def test_checkpoint_overhead_free_parity(self, host, tmp_path):
        """checkpoint= with no crash must not perturb anything, on every
        backend (the segmented driver replaces the single while_loop)."""
        s = session(host)
        prog = PageRankPushProgram(tol=1e-4)
        for backend in BACKENDS:
            pol = ExecutionPolicy(backend=backend)
            sem = s._sem(pol, prog)
            base = run_program(sem, prog, pol, max_supersteps=25)
            res = run_program(
                sem, prog, pol, max_supersteps=25,
                checkpoint=CheckpointSpec(tmp_path / backend, every_k=4))
            assert_identical(base, res)

    def test_finished_run_resumes_instantly(self, host, tmp_path):
        sem, _ = views(host)
        prog = PageRankPullProgram(tol=1e-4)
        spec = CheckpointSpec(tmp_path, every_k=4)
        first = run_program(sem, prog, max_supersteps=25, checkpoint=spec)
        again = run_program(sem, prog, max_supersteps=25, checkpoint=spec,
                            resume=True)
        assert_identical(first, again)

    def test_fingerprint_mismatch_raises(self, host, tmp_path):
        sem, _ = views(host)
        spec = CheckpointSpec(tmp_path, every_k=2)
        run_program(sem, PageRankPullProgram(tol=1e-3), max_supersteps=10,
                    checkpoint=spec)
        with pytest.raises(CheckpointMismatchError, match="program"):
            run_program(sem, PageRankPullProgram(tol=1e-5),
                        max_supersteps=10, checkpoint=spec, resume=True)
        with pytest.raises(CheckpointMismatchError, match="program"):
            run_program(sem, BFSProgram(), seeds=jnp.asarray([0], jnp.int32),
                        checkpoint=spec, resume=True)
        with pytest.raises(CheckpointMismatchError, match="seeds"):
            # same program class/config, different seeds
            spec2 = CheckpointSpec(tmp_path / "s", every_k=2)
            run_program(sem, BFSProgram(), seeds=jnp.asarray([0], jnp.int32),
                        checkpoint=spec2)
            run_program(sem, BFSProgram(), seeds=jnp.asarray([1], jnp.int32),
                        checkpoint=spec2, resume=True)

    def test_checkpoint_rejects_tracers(self, host, tmp_path):
        import jax

        sem, _ = views(host)
        with pytest.raises(ValueError, match="eagerly"):
            jax.jit(lambda: run_program(
                sem, PageRankPullProgram(), max_supersteps=5,
                checkpoint=CheckpointSpec(tmp_path)))()


# ------------------------------------------------------------ stream retry
class TestStreamRetry:
    def test_transient_faults_absorbed_and_counted(self, host):
        _, hv = views(host)
        prog = PageRankPullProgram(tol=1e-4)
        pol = ExecutionPolicy(residency="host", stream_backoff_s=0.0)
        base = run_program(hv, prog, pol, max_supersteps=10)
        assert int(base.iostats.retries) == 0

        calls = [0]

        def flaky():  # attempts 2 and 5 fail once each
            calls[0] += 1
            if calls[0] in (2, 5):
                raise OSError("transient link drop")

        with inject_stream_faults(flaky):
            res = run_program(hv, prog, pol, max_supersteps=10)
        assert int(res.iostats.retries) == 2
        # values and every other ledger field are untouched by the retries
        assert_identical(base, res, skip=("retries",))

    def test_exhaustion_raises_stream_failure(self, host):
        _, hv = views(host)
        pol = ExecutionPolicy(residency="host", stream_retries=2,
                              stream_backoff_s=0.0)

        def down():
            raise OSError("link down")

        with inject_stream_faults(down):
            with pytest.raises(StreamFailure, match="after 3 attempts"):
                run_program(hv, PageRankPullProgram(), pol, max_supersteps=5)

    def test_policy_validation(self):
        with pytest.raises(ValueError):
            ExecutionPolicy(stream_retries=-1)
        with pytest.raises(ValueError):
            ExecutionPolicy(stream_backoff_s=-0.1)


# ------------------------------------------------------------ work queue
def _work(src):
    out = np.zeros(16)
    for s in np.asarray(src).reshape(-1):
        out[int(s) % 16] += 0.1 * float(s) + 1.0
    return out


class TestWorkQueue:
    def make(self, **kw):
        kw.setdefault("result_template", np.zeros(16))
        kw.setdefault("clock", ManualClock())
        kw.setdefault("lease_timeout", 5.0)
        return WorkQueue(shard_sources(np.arange(23), 5), **kw)

    def test_lease_expiry_reissues(self):
        q = self.make()
        l1 = q.lease()
        assert (l1.tid, l1.attempt) == (0, 1)
        q._clock.advance(6.0)
        l2 = q.lease()  # the expired task comes back before task 1
        assert (l2.tid, l2.attempt) == (0, 2)
        # the dead worker's late result is a stale token: rejected
        assert not q.complete(l1, _work(l1.payload))
        assert not q.completed[0]
        assert q.complete(l2, _work(l2.payload))

    def test_dead_letter_after_max_attempts(self):
        q = self.make(max_attempts=2)
        run_workers(q, _work, deaths=[(0, 1), (0, 2)])
        assert q.dead_letters == [0]
        assert q.finished
        assert q.completed[1:].all()

    def test_merge_is_death_invariant(self):
        clean = run_workers(self.make(), _work)
        m0 = clean.merge(lambda a, b: a + b)
        # worker deaths mid-lease change the merged result by exactly nothing
        dead = run_workers(self.make(), _work,
                           deaths=[(1, 1), (3, 1), (3, 2), (4, 1)])
        m1 = dead.merge(lambda a, b: a + b)
        assert np.array_equal(m0, m1)
        assert dead.attempts[3] == 3

    def test_merge_order_is_canonical(self):
        """Completion order must not leak into the fold (float addition is
        not associative): complete tasks backwards, merge equal anyway."""
        fwd = run_workers(self.make(), _work)
        q = self.make()
        leases = [q.lease() for _ in range(q.num_tasks)]
        for l in reversed(leases):
            assert q.complete(l, _work(l.payload))
        assert np.array_equal(fwd.merge(lambda a, b: a + b),
                              q.merge(lambda a, b: a + b))

    def test_checkpoint_resume_mid_sweep(self, tmp_path):
        full = run_workers(self.make(), _work).merge(lambda a, b: a + b)
        q = self.make()
        for _ in range(2):
            l = q.lease()
            q.complete(l, _work(l.payload))
        q.checkpoint(tmp_path)
        # process dies here; a new queue over the same shards resumes
        q2 = self.make()
        assert q2.resume(tmp_path)
        assert int(q2.completed.sum()) == 2
        run_workers(q2, _work)
        assert np.array_equal(full, q2.merge(lambda a, b: a + b))

    def test_resume_rejects_different_sharding(self, tmp_path):
        q = self.make()
        l = q.lease()
        q.complete(l, _work(l.payload))
        q.checkpoint(tmp_path)
        other = WorkQueue(shard_sources(np.arange(23), 4),
                          result_template=np.zeros(16), clock=ManualClock())
        with pytest.raises(QueueMismatchError):
            other.resume(tmp_path)

    def test_resume_empty_dir_is_fresh_start(self, tmp_path):
        assert not self.make().resume(tmp_path / "nothing_here")

    def test_bc_sweep_through_queue(self, host, tmp_path):
        """End to end: exact-ish BC sharded over the queue; injected
        worker death changes the merged centrality by exactly nothing."""
        s = session(host)
        pol = ExecutionPolicy(backend="scan")
        shards = shard_sources(np.arange(6), 2)
        tpl = np.zeros(s.n, np.float32)

        def bc_shard(src):
            r = s.betweenness(jnp.asarray(src, jnp.int32), policy=pol)
            return np.asarray(r.values)

        def sweep(deaths):
            q = WorkQueue(shards, result_template=tpl, clock=ManualClock(),
                          lease_timeout=5.0)
            run_workers(q, bc_shard, deaths=deaths,
                        checkpoint_dir=tmp_path / f"q{len(deaths)}")
            return q.merge(lambda a, b: a + b)

        clean = sweep([])
        died = sweep([(0, 1), (2, 1)])
        assert np.array_equal(clean, died)
        # and the queue's own checkpoints are restorable
        q3 = WorkQueue(shards, result_template=tpl, clock=ManualClock())
        assert q3.resume(tmp_path / "q0")
        assert q3.finished
        assert np.array_equal(q3.merge(lambda a, b: a + b), clean)


# ------------------------------------------------------------ supervisor
class TestSupervisor:
    def test_gives_up_after_max_restarts(self, host, tmp_path):
        sem, _ = views(host)
        plan = FailurePlan({k: "crash" for k in range(0, 40)})
        with pytest.raises(DeviceFailure, match="gave up"):
            run_supervised(sem, PageRankPullProgram(tol=1e-4),
                           max_supersteps=25,
                           checkpoint=CheckpointSpec(tmp_path, every_k=2),
                           plan=plan, max_restarts=3)

    def test_report_records_resume_points(self, host, tmp_path):
        sem, _ = views(host)
        res, rep = run_supervised(
            sem, PageRankPullProgram(tol=1e-4), max_supersteps=25,
            checkpoint=CheckpointSpec(tmp_path, every_k=4),
            plan=FailurePlan({1: "crash", 9: "crash"}))
        assert rep.restarts == 2
        assert rep.resumed_steps == [None, 8]  # crash@1 pre-dates any save
        assert len(rep.log) == 2


# ------------------------------------------------------------ telemetry
class TestTelemetry:
    def test_sync_odometer(self, host, tmp_path):
        # The odometer records the checkpoint layer's synchronous seconds
        # and save count; equality/hash ignore it; child() phases share
        # (and accumulate into) the same dict.
        sem, _ = views(host)
        tele = {}
        spec = CheckpointSpec(tmp_path / "t", every_k=2, telemetry=tele)
        run_program(sem, PageRankPullProgram(tol=1e-4),
                    max_supersteps=10, checkpoint=spec)
        assert tele["saves"] >= 2
        assert tele["sync_s"] > 0.0
        assert spec.child("fwd").telemetry is tele
        assert spec == CheckpointSpec(tmp_path / "t", every_k=2)
