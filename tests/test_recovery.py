"""Fault tolerance: checkpoint durability, resume-exact BSP, stream retry,
and the lease-based work queue.

The contract under test is the strongest one the library can make: a run
killed at ANY superstep and resumed is *bitwise-equal* — values, superstep
count, and the full IOStats ledger (``host_bytes`` and ``retries``
included) — to a run that was never interrupted, on every backend and both
residencies; and a multi-source sweep whose workers die mid-lease merges
to exactly the same bits as one where nobody died.
"""
import os
import shutil

import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.checkpoint import (
    CheckpointCorruptionError,
    CheckpointManager,
    latest_step,
    load_extra,
    restore_checkpoint,
    save_checkpoint,
)
from repro.core import (
    CheckpointMismatchError,
    CheckpointSpec,
    DeviceFailure,
    ExecutionPolicy,
    FailurePlan,
    ManualClock,
    QueueMismatchError,
    StreamFailure,
    WorkQueue,
    inject_stream_faults,
    run_program,
    run_supervised,
    run_workers,
    shard_sources,
)
from repro.algs.bfs import BFSProgram
from repro.algs.pagerank import PageRankPullProgram, PageRankPushProgram
from repro.graph.generators import rmat

pytestmark = pytest.mark.kernel

BACKENDS = ("scan", "compact", "blocked", "blocked_compact")


@pytest.fixture(scope="module")
def host():
    # Small enough that kill-at-every-superstep sweeps stay fast, chunked
    # small enough that host streaming ships several batches per superstep.
    return rmat(6, edge_factor=6, seed=3, symmetrize=True)


def session(host):
    return repro.Graph(host, chunk_size=64, bd=32, bs=32)


def views(host):
    s = session(host)
    return s.device(), s.host_view()


def assert_identical(a, b, *, skip=()):
    """Full bitwise equality: values, supersteps, EVERY IOStats field."""
    assert np.array_equal(np.asarray(a.values), np.asarray(b.values))
    assert int(a.supersteps) == int(b.supersteps)
    for name, x, y in zip(a.iostats._fields, a.iostats, b.iostats):
        if name in skip:
            continue
        assert int(x) == int(y), f"IOStats.{name}: {int(x)} != {int(y)}"


# ------------------------------------------------------------ store
class TestStoreDurability:
    def test_tmp_partial_and_stray_entries_ignored(self, tmp_path):
        tree = {"a": jnp.arange(5), "b": jnp.ones(3)}
        save_checkpoint(tmp_path, 4, tree)
        # a crashed save leaves a .tmp; stray dirs happen to real operators
        (tmp_path / "step_00000099.tmp").mkdir()
        (tmp_path / "step_junk").mkdir()
        (tmp_path / "step_").mkdir()
        assert latest_step(tmp_path) == 4
        restored, step = restore_checkpoint(
            tmp_path, {"a": jnp.zeros(5, jnp.int32), "b": jnp.zeros(3)})
        assert step == 4
        assert np.array_equal(np.asarray(restored["a"]), np.arange(5))
        # retention gc must also step over the strays
        mgr = CheckpointManager(tmp_path, keep=1)
        mgr.save(7, tree)
        assert latest_step(tmp_path) == 7

    def test_corrupt_shard_is_an_error(self, tmp_path):
        save_checkpoint(tmp_path, 1, {"a": jnp.arange(4), "b": jnp.ones(2)})
        shard = tmp_path / "step_00000001" / "proc0.npz"
        np.savez(shard, a0=np.arange(4))  # one leaf missing
        with pytest.raises(CheckpointCorruptionError, match="manifest"):
            restore_checkpoint(
                tmp_path, {"a": jnp.zeros(4, jnp.int32), "b": jnp.zeros(2)})

    def test_extra_metadata_round_trip(self, tmp_path):
        save_checkpoint(tmp_path, 2, {"a": jnp.zeros(1)},
                        extra={"graph": "abc", "superstep": 2})
        assert load_extra(tmp_path, 2) == {"graph": "abc", "superstep": 2}
        assert load_extra(tmp_path, 3) is None

    def test_as_numpy_preserves_dtypes(self, tmp_path):
        save_checkpoint(tmp_path, 1, {"r": np.arange(3, dtype=np.float64)})
        tree, _ = restore_checkpoint(
            tmp_path, {"r": np.zeros(3, np.float64)}, as_numpy=True)
        assert tree["r"].dtype == np.float64


# ------------------------------------------------------------ resume-exact
class TestResumeExact:
    def test_kill_at_every_superstep(self, host, tmp_path):
        """The headline contract, exhaustively on one backend: crash at
        superstep k for EVERY k, resume, and the result is bitwise the
        uninterrupted run's — wherever k falls relative to every_k."""
        sem, _ = views(host)
        prog = PageRankPullProgram(tol=1e-4)
        base = run_program(sem, prog, max_supersteps=30)
        total = int(base.supersteps)
        assert total > 5
        for k in range(total):
            d = tmp_path / f"kill_{k}"
            res, rep = run_supervised(
                sem, prog, max_supersteps=30,
                checkpoint=CheckpointSpec(d, every_k=3),
                plan=FailurePlan({k: "crash"}))
            assert rep.restarts == 1
            assert_identical(base, res)

    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("residency", ("device", "host"))
    def test_backends_and_residencies(self, host, tmp_path, backend,
                                      residency):
        """Spot kills on every backend x residency: PageRank killed twice
        (once off-cadence), BFS killed once.  host_bytes and retries are
        compared too — same-residency runs must agree on the whole
        ledger."""
        s = session(host)
        pol = ExecutionPolicy(backend=backend, residency=residency)
        prog = PageRankPullProgram(tol=1e-4)
        sem = s._sem(pol, prog)  # the view the façade would run this on
        base = run_program(sem, prog, pol, max_supersteps=25)
        res, rep = run_supervised(
            sem, prog, pol, max_supersteps=25,
            checkpoint=CheckpointSpec(tmp_path / "pr", every_k=2),
            plan=FailurePlan({3: "crash", 7: "crash"}))
        assert rep.restarts == 2
        assert_identical(base, res)

        bfs = BFSProgram()
        seeds = jnp.asarray([0], jnp.int32)
        sem = s._sem(pol, bfs)
        base_b = run_program(sem, bfs, pol, seeds=seeds)
        res_b, _ = run_supervised(
            sem, bfs, pol, seeds=seeds,
            checkpoint=CheckpointSpec(tmp_path / "bfs", every_k=2),
            plan=FailurePlan({2: "crash"}))
        assert_identical(base_b, res_b)

    @pytest.mark.parametrize("residency", ("device", "host"))
    def test_betweenness_phase_checkpoints(self, host, tmp_path, residency):
        """A kill in the backward sweep resumes there; the forward phase
        replays from its final snapshot (its own `fwd/` subtree)."""
        s_base, s_ck = session(host), session(host)
        pol = ExecutionPolicy(backend="scan", residency=residency)
        src = jnp.arange(3)
        base = s_base.betweenness(src, policy=pol)
        spec = CheckpointSpec(tmp_path / "bc", every_k=2)
        ck = s_ck.betweenness(src, policy=pol, checkpoint=spec)
        assert_identical(base, ck)
        assert (tmp_path / "bc" / "fwd").is_dir()
        assert (tmp_path / "bc" / "bwd").is_dir()
        again = s_ck.betweenness(src, policy=pol, checkpoint=spec,
                                 resume=True)
        assert_identical(base, again)

    def test_checkpoint_overhead_free_parity(self, host, tmp_path):
        """checkpoint= with no crash must not perturb anything, on every
        backend (the segmented driver replaces the single while_loop)."""
        s = session(host)
        prog = PageRankPushProgram(tol=1e-4)
        for backend in BACKENDS:
            pol = ExecutionPolicy(backend=backend)
            sem = s._sem(pol, prog)
            base = run_program(sem, prog, pol, max_supersteps=25)
            res = run_program(
                sem, prog, pol, max_supersteps=25,
                checkpoint=CheckpointSpec(tmp_path / backend, every_k=4))
            assert_identical(base, res)

    def test_finished_run_resumes_instantly(self, host, tmp_path):
        sem, _ = views(host)
        prog = PageRankPullProgram(tol=1e-4)
        spec = CheckpointSpec(tmp_path, every_k=4)
        first = run_program(sem, prog, max_supersteps=25, checkpoint=spec)
        again = run_program(sem, prog, max_supersteps=25, checkpoint=spec,
                            resume=True)
        assert_identical(first, again)

    def test_fingerprint_mismatch_raises(self, host, tmp_path):
        sem, _ = views(host)
        spec = CheckpointSpec(tmp_path, every_k=2)
        run_program(sem, PageRankPullProgram(tol=1e-3), max_supersteps=10,
                    checkpoint=spec)
        with pytest.raises(CheckpointMismatchError, match="program"):
            run_program(sem, PageRankPullProgram(tol=1e-5),
                        max_supersteps=10, checkpoint=spec, resume=True)
        with pytest.raises(CheckpointMismatchError, match="program"):
            run_program(sem, BFSProgram(), seeds=jnp.asarray([0], jnp.int32),
                        checkpoint=spec, resume=True)
        with pytest.raises(CheckpointMismatchError, match="seeds"):
            # same program class/config, different seeds
            spec2 = CheckpointSpec(tmp_path / "s", every_k=2)
            run_program(sem, BFSProgram(), seeds=jnp.asarray([0], jnp.int32),
                        checkpoint=spec2)
            run_program(sem, BFSProgram(), seeds=jnp.asarray([1], jnp.int32),
                        checkpoint=spec2, resume=True)

    def test_checkpoint_rejects_tracers(self, host, tmp_path):
        import jax

        sem, _ = views(host)
        with pytest.raises(ValueError, match="eagerly"):
            jax.jit(lambda: run_program(
                sem, PageRankPullProgram(), max_supersteps=5,
                checkpoint=CheckpointSpec(tmp_path)))()


# ------------------------------------------------------------ stream retry
class TestStreamRetry:
    def test_transient_faults_absorbed_and_counted(self, host):
        _, hv = views(host)
        prog = PageRankPullProgram(tol=1e-4)
        pol = ExecutionPolicy(residency="host", stream_backoff_s=0.0)
        base = run_program(hv, prog, pol, max_supersteps=10)
        assert int(base.iostats.retries) == 0

        calls = [0]

        def flaky():  # attempts 2 and 5 fail once each
            calls[0] += 1
            if calls[0] in (2, 5):
                raise OSError("transient link drop")

        with inject_stream_faults(flaky):
            res = run_program(hv, prog, pol, max_supersteps=10)
        assert int(res.iostats.retries) == 2
        # values and every other ledger field are untouched by the retries
        assert_identical(base, res, skip=("retries",))

    def test_exhaustion_raises_stream_failure(self, host):
        _, hv = views(host)
        pol = ExecutionPolicy(residency="host", stream_retries=2,
                              stream_backoff_s=0.0)

        def down():
            raise OSError("link down")

        with inject_stream_faults(down):
            with pytest.raises(StreamFailure, match="after 3 attempts"):
                run_program(hv, PageRankPullProgram(), pol, max_supersteps=5)

    def test_policy_validation(self):
        with pytest.raises(ValueError):
            ExecutionPolicy(stream_retries=-1)
        with pytest.raises(ValueError):
            ExecutionPolicy(stream_backoff_s=-0.1)


# ------------------------------------------------------------ work queue
def _work(src):
    out = np.zeros(16)
    for s in np.asarray(src).reshape(-1):
        out[int(s) % 16] += 0.1 * float(s) + 1.0
    return out


class TestWorkQueue:
    def make(self, **kw):
        kw.setdefault("result_template", np.zeros(16))
        kw.setdefault("clock", ManualClock())
        kw.setdefault("lease_timeout", 5.0)
        return WorkQueue(shard_sources(np.arange(23), 5), **kw)

    def test_lease_expiry_reissues(self):
        q = self.make()
        l1 = q.lease()
        assert (l1.tid, l1.attempt) == (0, 1)
        q._clock.advance(6.0)
        l2 = q.lease()  # the expired task comes back before task 1
        assert (l2.tid, l2.attempt) == (0, 2)
        # the dead worker's late result is a stale token: rejected
        assert not q.complete(l1, _work(l1.payload))
        assert not q.completed[0]
        assert q.complete(l2, _work(l2.payload))

    def test_dead_letter_after_max_attempts(self):
        q = self.make(max_attempts=2)
        run_workers(q, _work, deaths=[(0, 1), (0, 2)])
        assert q.dead_letters == [0]
        assert q.finished
        assert q.completed[1:].all()

    def test_merge_is_death_invariant(self):
        clean = run_workers(self.make(), _work)
        m0 = clean.merge(lambda a, b: a + b)
        # worker deaths mid-lease change the merged result by exactly nothing
        dead = run_workers(self.make(), _work,
                           deaths=[(1, 1), (3, 1), (3, 2), (4, 1)])
        m1 = dead.merge(lambda a, b: a + b)
        assert np.array_equal(m0, m1)
        assert dead.attempts[3] == 3

    def test_merge_order_is_canonical(self):
        """Completion order must not leak into the fold (float addition is
        not associative): complete tasks backwards, merge equal anyway."""
        fwd = run_workers(self.make(), _work)
        q = self.make()
        leases = [q.lease() for _ in range(q.num_tasks)]
        for l in reversed(leases):
            assert q.complete(l, _work(l.payload))
        assert np.array_equal(fwd.merge(lambda a, b: a + b),
                              q.merge(lambda a, b: a + b))

    def test_checkpoint_resume_mid_sweep(self, tmp_path):
        full = run_workers(self.make(), _work).merge(lambda a, b: a + b)
        q = self.make()
        for _ in range(2):
            l = q.lease()
            q.complete(l, _work(l.payload))
        q.checkpoint(tmp_path)
        # process dies here; a new queue over the same shards resumes
        q2 = self.make()
        assert q2.resume(tmp_path)
        assert int(q2.completed.sum()) == 2
        run_workers(q2, _work)
        assert np.array_equal(full, q2.merge(lambda a, b: a + b))

    def test_resume_rejects_different_sharding(self, tmp_path):
        q = self.make()
        l = q.lease()
        q.complete(l, _work(l.payload))
        q.checkpoint(tmp_path)
        other = WorkQueue(shard_sources(np.arange(23), 4),
                          result_template=np.zeros(16), clock=ManualClock())
        with pytest.raises(QueueMismatchError):
            other.resume(tmp_path)

    def test_resume_empty_dir_is_fresh_start(self, tmp_path):
        assert not self.make().resume(tmp_path / "nothing_here")

    def test_bc_sweep_through_queue(self, host, tmp_path):
        """End to end: exact-ish BC sharded over the queue; injected
        worker death changes the merged centrality by exactly nothing."""
        s = session(host)
        pol = ExecutionPolicy(backend="scan")
        shards = shard_sources(np.arange(6), 2)
        tpl = np.zeros(s.n, np.float32)

        def bc_shard(src):
            r = s.betweenness(jnp.asarray(src, jnp.int32), policy=pol)
            return np.asarray(r.values)

        def sweep(deaths):
            q = WorkQueue(shards, result_template=tpl, clock=ManualClock(),
                          lease_timeout=5.0)
            run_workers(q, bc_shard, deaths=deaths,
                        checkpoint_dir=tmp_path / f"q{len(deaths)}")
            return q.merge(lambda a, b: a + b)

        clean = sweep([])
        died = sweep([(0, 1), (2, 1)])
        assert np.array_equal(clean, died)
        # and the queue's own checkpoints are restorable
        q3 = WorkQueue(shards, result_template=tpl, clock=ManualClock())
        assert q3.resume(tmp_path / "q0")
        assert q3.finished
        assert np.array_equal(q3.merge(lambda a, b: a + b), clean)


# ------------------------------------------------------------ supervisor
class TestSupervisor:
    def test_gives_up_after_max_restarts(self, host, tmp_path):
        sem, _ = views(host)
        plan = FailurePlan({k: "crash" for k in range(0, 40)})
        with pytest.raises(DeviceFailure, match="gave up"):
            run_supervised(sem, PageRankPullProgram(tol=1e-4),
                           max_supersteps=25,
                           checkpoint=CheckpointSpec(tmp_path, every_k=2),
                           plan=plan, max_restarts=3)

    def test_report_records_resume_points(self, host, tmp_path):
        sem, _ = views(host)
        res, rep = run_supervised(
            sem, PageRankPullProgram(tol=1e-4), max_supersteps=25,
            checkpoint=CheckpointSpec(tmp_path, every_k=4),
            plan=FailurePlan({1: "crash", 9: "crash"}))
        assert rep.restarts == 2
        assert rep.resumed_steps == [None, 8]  # crash@1 pre-dates any save
        assert len(rep.log) == 2


# ------------------------------------------------------------ telemetry
class TestTelemetry:
    def test_sync_odometer(self, host, tmp_path):
        # The odometer records the checkpoint layer's synchronous seconds
        # and save count; equality/hash ignore it; child() phases share
        # (and accumulate into) the same dict.
        sem, _ = views(host)
        tele = {}
        spec = CheckpointSpec(tmp_path / "t", every_k=2, telemetry=tele)
        run_program(sem, PageRankPullProgram(tol=1e-4),
                    max_supersteps=10, checkpoint=spec)
        assert tele["saves"] >= 2
        assert tele["sync_s"] > 0.0
        assert spec.child("fwd").telemetry is tele
        assert spec == CheckpointSpec(tmp_path / "t", every_k=2)


# ------------------------------------------------------------ torn metadata
class TestTornMetadata:
    def test_latest_step_skips_torn_extra(self, tmp_path):
        tree = {"a": jnp.arange(4)}
        save_checkpoint(tmp_path, 2, tree, extra={"fp": "ok"})
        save_checkpoint(tmp_path, 4, tree, extra={"fp": "ok"})
        # a crash/bit-flip truncates step 4's metadata mid-write
        (tmp_path / "step_00000004" / "extra.json").write_text('{"fp": "o')
        assert latest_step(tmp_path) == 2  # skipped, not crashed on
        restored, step = restore_checkpoint(tmp_path,
                                            {"a": jnp.zeros(4, jnp.int32)})
        assert step == 2

    def test_load_extra_raises_typed_error_naming_step(self, tmp_path):
        save_checkpoint(tmp_path, 7, {"a": jnp.zeros(1)}, extra={"x": 1})
        (tmp_path / "step_00000007" / "extra.json").write_text("")
        with pytest.raises(CheckpointCorruptionError, match="step 7"):
            load_extra(tmp_path, 7)

    def test_queue_resume_survives_torn_newest_snapshot(self, tmp_path):
        """End to end: the newest queue snapshot's metadata is torn; the
        resume scan falls back one step instead of crashing, and the
        sweep still finishes with the canonical merge."""
        shards = shard_sources(np.arange(23), 5)
        full = run_workers(
            WorkQueue(shards, result_template=np.zeros(16),
                      clock=ManualClock()), _work).merge(lambda a, b: a + b)
        q = WorkQueue(shards, result_template=np.zeros(16),
                      clock=ManualClock())
        for _ in range(3):
            l = q.lease()
            q.complete(l, _work(l.payload))
            q.checkpoint(tmp_path, keep=5)
        torn = tmp_path / "step_00000003" / "extra.json"
        torn.write_text(torn.read_text()[:10])
        q2 = WorkQueue(shards, result_template=np.zeros(16),
                       clock=ManualClock())
        assert q2.resume(tmp_path)
        assert int(q2.completed.sum()) == 2  # one step of progress lost
        run_workers(q2, _work)
        assert np.array_equal(full, q2.merge(lambda a, b: a + b))


# ------------------------------------------------------------ streaming store
class TestStreamingStore:
    def test_sharded_save_bounded_staging_bitwise_restore(self, tmp_path):
        """State >> max_shard_bytes: many fsync'd shards, measured peak
        staging <= one shard budget, restore bitwise across dtypes."""
        rng = np.random.default_rng(0)
        tree = {
            "big": rng.standard_normal(16384).astype(np.float32),  # 64 KiB
            "ints": np.arange(5000, dtype=np.int64),
            "flags": rng.random(333) < 0.5,
            "scalar": np.float64(1.25),
        }
        tel = {}
        budget = 8192
        save_checkpoint(tmp_path, 3, tree, max_shard_bytes=budget,
                        telemetry=tel)
        shards = sorted((tmp_path / "step_00000003").glob("shard_*.npz"))
        assert len(shards) >= 8  # 64K floats alone need 8 shards
        assert 0 < tel["stage_peak_bytes"] <= budget
        assert tel["shard_files"] == len(shards)
        restored, step = restore_checkpoint(tmp_path, tree, as_numpy=True)
        assert step == 3
        for k, v in tree.items():
            assert np.array_equal(np.asarray(restored[k]), np.asarray(v)), k
            assert np.asarray(restored[k]).dtype == np.asarray(v).dtype

    def test_streaming_handles_jax_and_mldtype_leaves(self, tmp_path):
        tree = {"bf": jnp.arange(3000, dtype=jnp.bfloat16),
                "f": jnp.linspace(0, 1, 700)}
        save_checkpoint(tmp_path, 1, tree, max_shard_bytes=1024)
        restored, _ = restore_checkpoint(tmp_path, tree)
        for k in tree:
            assert restored[k].dtype == tree[k].dtype
            assert np.array_equal(np.asarray(restored[k]),
                                  np.asarray(tree[k])), k

    def test_delta_skips_unchanged_pieces(self, tmp_path):
        """Second snapshot of a mostly-unchanged state stores only the
        changed pieces; restore resolves references to the base step."""
        import json
        tree = {"big": np.arange(8192, dtype=np.float32),
                "tick": np.int64(0)}
        save_checkpoint(tmp_path, 1, tree, max_shard_bytes=4096, delta=True)
        full_bytes = json.loads(
            (tmp_path / "step_00000001" / "manifest.json").read_text()
        )["stored_bytes"]
        tree2 = dict(tree)
        tree2["tick"] = np.int64(1)  # only the odometer moved
        save_checkpoint(tmp_path, 2, tree2, max_shard_bytes=4096, delta=True)
        m2 = json.loads(
            (tmp_path / "step_00000002" / "manifest.json").read_text())
        assert m2["stored_bytes"] * 2 < full_bytes  # >=2x smaller
        # pieces of the unchanged leaf reference step 1's physical copy
        refs = {p["step"] for p in m2["leaves"][0]["pieces"]}
        assert refs == {1}
        restored, step = restore_checkpoint(tmp_path, tree2, as_numpy=True)
        assert step == 2
        assert np.array_equal(restored["big"], tree2["big"])
        assert int(restored["tick"]) == 1

    def test_delta_references_collapse_to_physical_home(self, tmp_path):
        """A long delta chain never deepens: step k references the step
        that STORES each piece, not step k-1 — restore is one hop."""
        import json
        tree = {"big": np.zeros(4096, np.float32), "t": np.int64(0)}
        for s in range(1, 6):
            tree = dict(tree, t=np.int64(s))
            save_checkpoint(tmp_path, s, tree, delta=True)
        m = json.loads(
            (tmp_path / "step_00000005" / "manifest.json").read_text())
        assert {p["step"] for p in m["leaves"][0]["pieces"]} == {1}
        restored, _ = restore_checkpoint(tmp_path, tree, as_numpy=True)
        assert int(restored["t"]) == 5

    def test_gc_retains_delta_referenced_base(self, tmp_path):
        """Retention keeps a step alive while newer snapshots reference
        its shards — deleting it would orphan every delta above it."""
        mgr = CheckpointManager(tmp_path, keep=2, delta=True,
                                max_shard_bytes=4096)
        tree = {"big": np.arange(4096, dtype=np.float32), "t": np.int64(0)}
        for s in range(6):
            mgr.save(s, dict(tree, t=np.int64(s)))
        kept = sorted(p.name for p in tmp_path.iterdir()
                      if p.name.startswith("step_"))
        assert "step_00000000" in kept  # the physical home survives
        restored, step = mgr.restore(tree)
        assert step == 5
        assert np.array_equal(np.asarray(restored["big"]), tree["big"])

    def test_missing_referenced_shard_is_corruption_error(self, tmp_path):
        tree = {"big": np.zeros(4096, np.float32), "t": np.int64(0)}
        save_checkpoint(tmp_path, 1, tree, delta=True)
        save_checkpoint(tmp_path, 2, dict(tree, t=np.int64(1)), delta=True)
        shutil.rmtree(tmp_path / "step_00000001")  # deleted out of band
        with pytest.raises(CheckpointCorruptionError, match="shard"):
            restore_checkpoint(tmp_path, tree, 2)

    def test_spec_threads_streaming_delta_through_driver(self, host,
                                                         tmp_path):
        """CheckpointSpec(max_shard_bytes, delta) reach the BSP driver's
        snapshots, and kill-resume parity still holds bitwise."""
        sem, _ = views(host)
        prog = PageRankPullProgram(tol=1e-4)
        base = run_program(sem, prog, max_supersteps=25)
        res, rep = run_supervised(
            sem, prog, max_supersteps=25,
            checkpoint=CheckpointSpec(tmp_path / "d", every_k=3,
                                      max_shard_bytes=2048, delta=True,
                                      async_save=False),
            plan=FailurePlan({7: "crash"}))
        assert rep.restarts == 1
        assert_identical(base, res)
        import json
        steps = sorted((tmp_path / "d").glob("step_*/manifest.json"))
        assert steps, "driver produced no streaming snapshots"
        m = json.loads(steps[-1].read_text())
        assert m.get("format") == 2  # the streaming layout, not legacy

    def test_spec_validates_shard_bytes(self, tmp_path):
        with pytest.raises(ValueError):
            CheckpointSpec(tmp_path, max_shard_bytes=0)


# ------------------------------------------------- wall-clock lease expiry
class TestWallClockExpiry:
    def test_dead_worker_task_reissued_on_real_clock(self):
        """The default-clock contract, no ManualClock: a worker that goes
        silent past a real (tiny) lease_timeout loses its task to the
        next lease(), and its late result is a stale token."""
        import time as _time
        q = WorkQueue(shard_sources(np.arange(6), 3),
                      lease_timeout=0.1, result_template=np.zeros(16))
        assert q._clock is _time.monotonic  # the documented default
        l1 = q.lease()
        assert (l1.tid, l1.attempt) == (0, 1)
        _time.sleep(0.25)  # the worker is presumed dead
        l2 = q.lease()
        assert (l2.tid, l2.attempt) == (0, 2)  # re-issued, not stuck
        assert q.complete(l2, _work(l2.payload))
        assert not q.complete(l1, _work(l1.payload))  # late == stale
        assert q.completed[0]

    def test_late_complete_before_reap_still_commits(self):
        """Lazy expiry: an expired-but-unreaped lease can still commit —
        nothing observed the expiry, so the work is not wasted."""
        import time as _time
        q = WorkQueue(shard_sources(np.arange(3), 3),
                      lease_timeout=0.05, result_template=np.zeros(16))
        l1 = q.lease()
        _time.sleep(0.1)  # expired on the wall clock, but nobody reaped
        assert q.complete(l1, _work(l1.payload))


# ------------------------------------------------- batched stream retry
class TestBatchedStreamRetry:
    def test_transient_faults_absorbed_bitwise_per_query(self, host):
        """(n, Q) host-streamed run under transient stream faults: the
        retries land in IOStats.retries and NOTHING else moves — values,
        per-query supersteps, and every other ledger field are bitwise
        the fault-free run's."""
        from repro.core import run_program_batched

        _, hv = views(host)
        prog = BFSProgram()
        pol = ExecutionPolicy(residency="host", stream_backoff_s=0.0)
        seeds = jnp.asarray([0, 3, 11], jnp.int32)
        base = run_program_batched(hv, prog, pol, seeds=seeds)
        assert int(base.iostats.retries) == 0

        calls = [0]

        def flaky():  # two transient drops mid-sweep
            calls[0] += 1
            if calls[0] in (2, 4):
                raise OSError("transient link drop")

        with inject_stream_faults(flaky):
            res = run_program_batched(hv, prog, pol, seeds=seeds)
        assert int(res.iostats.retries) == 2
        assert_identical(base, res, skip=("retries",))
        assert np.array_equal(np.asarray(base.query_supersteps),
                              np.asarray(res.query_supersteps))

    def test_exhaustion_leaves_no_half_committed_checkpoint(self, host,
                                                            tmp_path):
        """StreamFailure after retry exhaustion mid-(n, Q) run: the
        checkpoint directory holds only COMPLETE snapshots (or none), and
        resuming from it converges to the bitwise fault-free result."""
        from repro.core import run_program_batched

        _, hv = views(host)
        prog = BFSProgram()
        seeds = jnp.asarray([0, 3, 11], jnp.int32)
        pol = ExecutionPolicy(residency="host", stream_retries=1,
                              stream_backoff_s=0.0)
        base = run_program_batched(hv, prog, pol, seeds=seeds)

        calls = [0]

        def dies_later():  # healthy start, then the link goes down hard
            calls[0] += 1
            if calls[0] >= 3:
                raise OSError("link down")

        d = tmp_path / "b"
        spec = CheckpointSpec(d, every_k=1, async_save=False)
        with inject_stream_faults(dies_later):
            with pytest.raises(StreamFailure):
                run_program_batched(hv, prog, pol, seeds=seeds,
                                    checkpoint=spec)
        step = latest_step(d)
        if step is not None:  # whatever was published must be restorable
            assert load_extra(d, step) is not None
        res = run_program_batched(hv, prog, pol, seeds=seeds,
                                  checkpoint=spec, resume=True)
        assert_identical(base, res, skip=("retries",))
        assert np.array_equal(np.asarray(base.query_supersteps),
                              np.asarray(res.query_supersteps))
