"""Expert-parallel MoE (shard_map all-to-all) vs the dense oracle.

The EP path needs >1 device on the 'model' axis, so the check runs in a
subprocess with forced host devices (the same mechanism as the dry-run;
the pytest process itself must keep seeing 1 device).
"""
import json
import subprocess
import sys
import textwrap

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.base import ModelConfig
    from repro.models.moe import init_moe, moe_ffn, moe_ffn_ep
    from repro.models.param import Mk, split

    cfg = ModelConfig(
        name="moe-test", family="moe", n_layers=1, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=96, vocab=128, n_experts=8, top_k=2,
        capacity_factor=8.0,  # headroom: no drops => exact parity
    )
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    params = init_moe(Mk(jax.random.key(0)), cfg)
    p, _ = split(params)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 8, 64)).astype(np.float32) * 0.1,
                    jnp.bfloat16)

    y_ref, aux_ref = jax.jit(lambda p, x: moe_ffn(p, x, cfg))(p, x)
    y_ep, aux_ep = jax.jit(lambda p, x: moe_ffn_ep(p, x, cfg, mesh))(p, x)

    a = np.asarray(y_ref, np.float32)
    b = np.asarray(y_ep, np.float32)
    err = float(np.max(np.abs(a - b)))
    rel = err / max(float(np.abs(a).max()), 1e-6)
    print(json.dumps({
        "err": err, "rel": rel,
        "aux_ref": float(aux_ref), "aux_ep": float(aux_ep),
    }))
    """
)


def test_ep_matches_dense_oracle():
    r = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True,
        text=True,
        timeout=600,
        cwd="/root/repo",
    )
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    # bf16 tile math: parity to bf16 tolerance
    assert out["rel"] < 0.05, out
    # aux loss is a pmean of per-shard Switch losses; with sharded token
    # populations it is a close estimate, not bit-equal
    assert abs(out["aux_ref"] - out["aux_ep"]) < 0.5 * abs(out["aux_ref"]) + 0.2, out
